"""reprolint checker suite: each checker catches a seeded violation of
its invariant class and stays quiet on the clean twin, suppressions and
baselines behave, and the repo-wide run matches the committed baseline
EXACTLY (0 new findings, 0 stale entries) — so the suite fails loudly
the moment someone reintroduces a burned-down bug class OR fixes debt
without updating the baseline.

Fixture files are written under ``tmp_path/repro/...`` because path
scoping (hot-path checker only in ``serving/engine.py``, determinism
only in virtual-time modules) keys on the repo-relative suffix after
the last ``repro/`` marker — exactly how fingerprints stay stable
across checkouts.
"""
from pathlib import Path

import pytest

from repro.analysis.base import Finding, rel_path
from repro.analysis.lint import ALL_CHECKERS, run_lint
from repro.analysis import load_baseline

REPO = Path(__file__).resolve().parents[1]


def _write(tmp_path: Path, rel: str, text: str) -> Path:
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return p


def _lint(tmp_path, rel, text, checker=None):
    p = _write(tmp_path, rel, text)
    checkers = [c for c in ALL_CHECKERS if checker is None
                or c.name == checker]
    return run_lint([p], checkers=checkers)


def _names(res):
    return sorted(f.checker for f in res.new)


# ---------------------------------------------------------------------------
# sync-point
# ---------------------------------------------------------------------------

SYNC_VIOLATION = """
import numpy as np

class JaxEngine:
    def execute_run(self, model, sb, node_ids):
        for nid in node_ids:
            toks = self._dispatch(nid)
            val = toks.item()            # hidden per-node sync!
        return 0.0, None
"""

SYNC_CLEAN = """
import numpy as np

class JaxEngine:
    def execute_run(self, model, sb, node_ids):
        out = self._dispatch(node_ids)
        arr = np.asarray(out)  # reprolint: disable=sync-point
        return 0.0, None

    def debug_dump(self):
        # not a hot function: syncing here is fine
        return [np.asarray(a) for a in self.arenas]
"""


def test_sync_point_catches_hidden_sync_in_hot_path(tmp_path):
    res = _lint(tmp_path, "repro/serving/engine.py", SYNC_VIOLATION,
                checker="sync-point")
    assert _names(res) == ["sync-point"]
    assert "execute_run" in res.new[0].message


def test_sync_point_respects_suppression_and_cold_functions(tmp_path):
    res = _lint(tmp_path, "repro/serving/engine.py", SYNC_CLEAN,
                checker="sync-point")
    assert res.new == []


def test_sync_point_scoped_to_engine_module(tmp_path):
    # the same construct in a non-engine file is out of scope
    res = _lint(tmp_path, "repro/serving/metrics.py", SYNC_VIOLATION,
                checker="sync-point")
    assert res.new == []


# ---------------------------------------------------------------------------
# retrace-hazard
# ---------------------------------------------------------------------------

RETRACE_VIOLATION = """
class JaxEngine:
    def execute_run(self, model, sb, node_ids):
        sts = [self.states[r.rid] for r in sb.live_requests]
        # unbucketed batch size in the jit-cache key: one compile per B
        fn = self._fn_mega(0, len(sts), True, sts[0].pos)
        return fn(self.params)
"""

RETRACE_CLEAN = """
class JaxEngine:
    def execute_run(self, model, sb, node_ids):
        sts = [self.states[r.rid] for r in sb.live_requests]
        fn = self._fn_mega(0, _pow2(len(sts)), True,
                           _pow2(sts[0].pos))
        return fn(self.params)
"""

JIT_OUTSIDE_GETTER = """
import jax

class JaxEngine:
    def execute_run(self, model, sb, node_ids):
        fn = jax.jit(lambda x: x + 1)    # uncached jit: retrace per call
        return fn(1.0)
"""


def test_retrace_catches_unbucketed_dynamic_scalars(tmp_path):
    res = _lint(tmp_path, "repro/serving/engine.py", RETRACE_VIOLATION,
                checker="retrace-hazard")
    assert len(res.new) >= 1
    assert all(f.checker == "retrace-hazard" for f in res.new)


def test_retrace_accepts_pow2_bucketed_args(tmp_path):
    res = _lint(tmp_path, "repro/serving/engine.py", RETRACE_CLEAN,
                checker="retrace-hazard")
    assert res.new == []


def test_retrace_flags_jit_outside_cached_getter(tmp_path):
    res = _lint(tmp_path, "repro/serving/engine.py", JIT_OUTSIDE_GETTER,
                checker="retrace-hazard")
    assert _names(res) == ["retrace-hazard"]


# ---------------------------------------------------------------------------
# bare-assert
# ---------------------------------------------------------------------------

def test_bare_assert_flags_runtime_invariant(tmp_path):
    res = _lint(tmp_path, "repro/serving/foo.py",
                "def f(x):\n    assert x > 0, 'bad'\n    return x\n",
                checker="bare-assert")
    assert _names(res) == ["bare-assert"]
    assert "python -O" in res.new[0].message


def test_bare_assert_suppression_on_preceding_line(tmp_path):
    res = _lint(tmp_path, "repro/serving/foo.py",
                "def f(x):\n"
                "    # reprolint: disable=bare-assert\n"
                "    assert x > 0\n",
                checker="bare-assert")
    assert res.new == []


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

DET_VIOLATIONS = """
import time
import random
import numpy as np

def schedule(queue):
    t = time.time()                      # wall clock in sim path
    rng = np.random.default_rng()        # unseeded
    jitter = random.random()             # global stdlib RNG
    pick = np.random.rand()              # numpy GLOBAL RNG
    best = min({q.name for q in queue}, key=lambda n: len(n))
    return t, rng, jitter, pick, best
"""

DET_CLEAN = """
import numpy as np

def schedule(queue, now, seed):
    rng = np.random.default_rng(seed)            # seeded: fine
    names = sorted({q.name for q in queue})      # key-less: total order
    return now + rng.exponential(1.0), names
"""


def test_determinism_catches_all_violation_kinds(tmp_path):
    res = _lint(tmp_path, "repro/core/sched.py", DET_VIOLATIONS,
                checker="determinism")
    assert len(res.new) == 4
    msgs = " ".join(f.message for f in res.new)
    assert "without a seed" in msgs
    assert "stdlib" in msgs
    assert "GLOBAL" in msgs
    assert "set iteration" in msgs
    # wall-clock reads moved to the interprocedural wallclock-taint
    # checker (see test_dataflow.py) — determinism must NOT double-report
    assert "wall-clock" not in msgs


def test_determinism_clean_patterns_pass(tmp_path):
    res = _lint(tmp_path, "repro/core/sched.py", DET_CLEAN,
                checker="determinism")
    assert res.new == []


def test_determinism_scoped_to_virtual_time_modules(tmp_path):
    # launch/train.py is NOT a virtual-time module: wall clock is fine
    res = _lint(tmp_path, "repro/launch/train.py", DET_VIOLATIONS,
                checker="determinism")
    assert res.new == []


# ---------------------------------------------------------------------------
# backend-contract
# ---------------------------------------------------------------------------

CONTRACT_VIOLATION = """
from repro.serving.backend import Backend

class DriftingBackend(Backend):
    def execute(self, sb, node_id):      # lost the model key!
        return 0.0

    def memory_stats(self, which=None):  # renamed the model key!
        return None
"""

CONTRACT_CLEAN = """
from repro.serving.backend import Backend

class GoodBackend(Backend):
    def execute(self, model, sb, node_id):
        return 0.0

    def helper(self, x):                 # non-contract method: free-form
        return x
"""

EXECUTOR_USE = """
from repro.serving.server import Executor

def build():
    return Executor()
"""


def test_contract_catches_signature_drift(tmp_path):
    res = _lint(tmp_path, "repro/serving/custom.py", CONTRACT_VIOLATION,
                checker="backend-contract")
    assert len(res.new) == 2
    assert all("model-keyed" in f.message for f in res.new)


def test_contract_accepts_conforming_subclass(tmp_path):
    res = _lint(tmp_path, "repro/serving/custom.py", CONTRACT_CLEAN,
                checker="backend-contract")
    assert res.new == []


def test_contract_flags_retired_executor_alias(tmp_path):
    res = _lint(tmp_path, "repro/serving/custom.py", EXECUTOR_USE,
                checker="backend-contract")
    assert len(res.new) >= 1
    assert all("Executor" in f.message for f in res.new)


# ---------------------------------------------------------------------------
# swallowed-exception
# ---------------------------------------------------------------------------

SWALLOW_VIOLATIONS = """
def fetch(x):
    try:
        return x.value()
    except:
        return None

def probe(x):
    try:
        x.poke()
    except Exception:
        pass
"""

SWALLOW_CLEAN = """
def fetch(x):
    try:
        return x.value()
    except KeyError:
        return None

def probe(x):
    try:
        x.poke()
    except Exception as e:
        record(e)                        # handled, not swallowed

def relay(x):
    try:
        return x.value()
    except:
        raise                            # bare but transparent
"""

def test_swallow_flags_bare_and_trivial_handlers(tmp_path):
    res = _lint(tmp_path, "repro/launch/foo.py", SWALLOW_VIOLATIONS,
                checker="swallowed-exception")
    assert len(res.new) == 2
    msgs = " ".join(f.message for f in res.new)
    assert "bare" in msgs and "black hole" in msgs


def test_swallow_accepts_specific_recorded_or_reraised(tmp_path):
    res = _lint(tmp_path, "repro/launch/foo.py", SWALLOW_CLEAN,
                checker="swallowed-exception")
    assert res.new == []


def test_swallow_no_longer_owns_the_slot_rule(tmp_path):
    # the syntactic slot rule (old rule B) is superseded by the
    # path-sensitive slot-leak checker (test_dataflow.py): the shape it
    # used to pattern-match is out of swallowed-exception's scope now
    leaky = ("class Engine:\n"
             "    def dispatch(self, model, req):\n"
             "        try:\n"
             "            slot = self.slot_of(req)\n"
             "            return self._run(slot)\n"
             "        except RuntimeError:\n"
             "            return None\n")
    res = _lint(tmp_path, "repro/serving/custom.py", leaky,
                checker="swallowed-exception")
    assert res.new == []


# ---------------------------------------------------------------------------
# fingerprints and baselines
# ---------------------------------------------------------------------------

def test_fingerprint_survives_unrelated_edits(tmp_path):
    before = "def f(x):\n    assert x > 0\n"
    after = "import os\n\n\ndef g():\n    pass\n\n\ndef f(x):\n    assert x > 0\n"
    f1 = _lint(tmp_path / "a", "repro/serving/foo.py", before,
               checker="bare-assert").new[0]
    f2 = _lint(tmp_path / "b", "repro/serving/foo.py", after,
               checker="bare-assert").new[0]
    assert f1.line != f2.line            # the site moved...
    assert f1.fingerprint == f2.fingerprint  # ...the identity did not


def test_baseline_splits_new_known_and_stale(tmp_path):
    two = "def f(x):\n    assert x > 0\n    assert x < 9\n"
    res = _lint(tmp_path, "repro/serving/foo.py", two,
                checker="bare-assert")
    baseline = [{"fingerprint": res.new[0].fingerprint,
                 "checker": "bare-assert", "path": res.new[0].path},
                {"fingerprint": "feedfacedeadbeef",
                 "checker": "bare-assert", "path": "repro/gone.py"}]
    p = tmp_path / "repro/serving/foo.py"
    res2 = run_lint([p], checkers=[c for c in ALL_CHECKERS
                                   if c.name == "bare-assert"],
                    baseline=baseline)
    assert len(res2.new) == 1            # the un-baselined assert
    assert len(res2.baselined) == 1      # the pinned one
    assert len(res2.stale) == 1          # the paid-down debt
    assert not res2.ok


# ---------------------------------------------------------------------------
# the repo itself
# ---------------------------------------------------------------------------

def test_repo_matches_committed_baseline_exactly():
    """The gate CI runs: linting ``src/``, ``tests/`` and
    ``benchmarks/`` with all nine checkers against the committed
    baseline yields zero NEW findings and zero STALE entries. If this
    fails you either introduced a violation (fix it) or fixed known
    debt (regenerate the baseline with --write-baseline and commit the
    shrunken file)."""
    baseline = load_baseline(REPO / "reprolint.baseline.json")
    res = run_lint([REPO / "src", REPO / "tests", REPO / "benchmarks"],
                   baseline=baseline)
    assert res.new == [], "\n".join(str(f) for f in res.new)
    assert res.stale == [], f"stale baseline entries: {res.stale}"
    # the debt is fully burned down: the baseline stays EMPTY
    assert res.baselined == [], \
        "the baseline must stay empty — fix the finding instead of " \
        "re-pinning it"


def test_rel_path_normalizes_across_checkouts():
    assert rel_path("/home/x/repo/src/repro/serving/engine.py") \
        == "repro/serving/engine.py"
    assert rel_path("/tmp/pytest-1/repro/serving/engine.py") \
        == "repro/serving/engine.py"
    assert rel_path("/home/x/repo/tests/test_session.py") \
        == "tests/test_session.py"
    assert rel_path("/home/x/repo/benchmarks/fig5_time_window.py") \
        == "benchmarks/fig5_time_window.py"


# ---------------------------------------------------------------------------
# scoped checker sets outside src/
# ---------------------------------------------------------------------------

def test_bare_assert_exempt_in_tests(tmp_path):
    # pytest asserts ARE the assertion mechanism in tests
    res = _lint(tmp_path, "tests/test_foo.py",
                "def test_x():\n    assert 1 + 1 == 2\n",
                checker="bare-assert")
    assert res.new == []


def test_bare_assert_still_active_in_benchmarks(tmp_path):
    res = _lint(tmp_path, "benchmarks/bench_foo.py",
                "def run(x):\n    assert x > 0\n",
                checker="bare-assert")
    assert _names(res) == ["bare-assert"]


def test_determinism_active_in_fig_benchmarks(tmp_path):
    # fig* benches ARE the paper's deterministic artifacts
    res = _lint(tmp_path, "benchmarks/fig5_time_window.py", DET_VIOLATIONS,
                checker="determinism")
    assert len(res.new) == 4


def test_determinism_inactive_in_wall_time_benchmarks(tmp_path):
    res = _lint(tmp_path, "benchmarks/engine_decode_bench.py",
                DET_VIOLATIONS, checker="determinism")
    assert res.new == []


def test_executor_reference_rule_exempt_in_tests(tmp_path):
    res = _lint(tmp_path, "tests/test_compat.py", EXECUTOR_USE,
                checker="backend-contract")
    assert res.new == []


def test_contract_requires_residency_pair(tmp_path):
    half = ("class SimBackend:\n"
            "    def reset_request(self, model, req):\n"
            "        pass\n")
    res = _lint(tmp_path, "repro/serving/custom.py", half,
                checker="backend-contract")
    assert len(res.new) == 1
    assert "release_request" in res.new[0].message
    both = half + ("\n    def release_request(self, model, req):\n"
                   "        pass\n")
    res2 = _lint(tmp_path / "b", "repro/serving/custom.py", both,
                 checker="backend-contract")
    assert res2.new == []


# ---------------------------------------------------------------------------
# the --cache layer
# ---------------------------------------------------------------------------

def test_cache_reuses_results_and_keeps_project_facts(tmp_path):
    """Warm-cache runs must reproduce per-file findings AND still give
    the project checkers the full fact set (the wallclock-taint chain
    crosses a cached and a fresh file)."""
    helper = _write(tmp_path, "src/repro/launch/helper.py",
                    "import time\n\n\ndef stamp():\n"
                    "    return time.perf_counter()\n")
    sink = _write(tmp_path, "src/repro/core/sched.py",
                  "from repro.launch.helper import stamp\n\n\n"
                  "def schedule(queue):\n    return stamp()\n")
    cache = tmp_path / "cache.json"
    cold = run_lint([helper, sink], cache_path=cache)
    assert [f.checker for f in cold.new] == ["wallclock-taint"]
    assert cache.exists()
    warm = run_lint([helper, sink], cache_path=cache)
    assert [(f.checker, f.path, f.line, f.fingerprint) for f in warm.new] \
        == [(f.checker, f.path, f.line, f.fingerprint) for f in cold.new]


def test_stale_cache_version_is_recomputed_not_reused(tmp_path):
    """A cache written under an older CACHE_VERSION must be discarded
    wholesale: v1 facts lack the async effect summaries (is_async /
    awaited / suppressed_blocking) the async checkers read, so reusing
    them would silently blind blocking-in-async on unchanged files."""
    import json

    from repro.analysis import lint as lint_mod

    p = _write(tmp_path, "src/repro/serving/gateway/gw.py",
               "import time\n\n\nasync def handler():\n"
               "    time.sleep(1)\n")
    cache = tmp_path / "cache.json"
    cold = run_lint([p], cache_path=cache)
    assert "blocking-in-async" in _names(cold)
    # regress the on-disk cache to the previous schema version, with
    # entries a naive loader would happily reuse (hash matches because
    # we keep the v2 hashes — only the envelope version is old)
    doc = json.loads(cache.read_text())
    doc["version"] = lint_mod.CACHE_VERSION - 1
    for entry in doc["files"].values():
        for fn in entry["facts"]["functions"].values():
            fn.pop("is_async", None)         # v1 facts had no summaries
    cache.write_text(json.dumps(doc))
    warm = run_lint([p], cache_path=cache)
    assert _names(warm) == _names(cold)
    assert json.loads(cache.read_text())["version"] \
        == lint_mod.CACHE_VERSION


def test_cache_invalidated_by_content_change(tmp_path):
    p = _write(tmp_path, "src/repro/serving/foo.py",
               "def f(x):\n    assert x > 0\n")
    cache = tmp_path / "cache.json"
    first = run_lint([p], cache_path=cache)
    assert _names(first) == ["bare-assert"]
    p.write_text("def f(x):\n    if x <= 0:\n"
                 "        raise ValueError(x)\n")
    second = run_lint([p], cache_path=cache)
    assert second.new == []


# ---------------------------------------------------------------------------
# --format github
# ---------------------------------------------------------------------------

def test_github_format_emits_error_annotations(tmp_path, capsys):
    from repro.analysis.lint import main
    p = _write(tmp_path, "repro/serving/foo.py",
               "def f(x):\n    assert x > 0\n")
    empty = _write(tmp_path, "empty-baseline.json", '{"findings": []}')
    rc = main([str(p), "--format", "github", "--baseline", str(empty)])
    out = capsys.readouterr().out
    assert rc == 1
    line = next(l for l in out.splitlines() if l.startswith("::error"))
    assert f"file={p}" in line
    assert "line=2" in line
    assert "title=reprolint bare-assert" in line


def test_github_format_escapes_newlines_and_percent():
    from repro.analysis.lint import _escape_gha
    assert _escape_gha("a\nb%c") == "a%0Ab%25c"
