"""Property-based tests (hypothesis) on scheduler/system invariants.

Invariants checked for EVERY policy on random traces:
  * every request completes exactly once, with finish >= arrival,
  * node-execution order per request equals its sequence (no skips),
  * sub-batches never exceed the model-allowed max batch size,
  * BatchTable entries never hold requests at different nodes,
  * GraphB never dispatches a batch before its window/size trigger.

Plus LazyBatching-specific: under the predictor's own latency model, any
request admitted *while the server was idle-free* is never predicted to
violate at admission time (conservative authorization).
"""
import pytest

pytest.importorskip("hypothesis", reason="install the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core import (Serial, GraphBatching, CellularBatching, LazyBatching,
                        Oracle, SlackPredictor, OracleSlackPredictor)
from repro.serving import (get_workload, poisson_trace, NPUPerfModel,
                           PAPER_NPU)
from repro.serving.server import InferenceServer, SimExecutor

PERF = NPUPerfModel(PAPER_NPU)
WORKLOADS = {name: get_workload(name) for name in ["resnet", "transformer"]}


class CheckingExecutor(SimExecutor):
    """Executor that verifies per-request node order and batch bounds.

    Under the run-commit contract the policy hands over a run of
    consecutive node ids: it must be a prefix of EVERY live member's
    remaining sequence (no member may finish mid-run — completions are
    run-boundary events).
    """

    def __init__(self, perf, max_batch):
        super().__init__(perf)
        self.max_batch = max_batch
        self.executed = {}          # rid -> list of node ids
        self.run_lengths = []

    def execute_run(self, model, sb, node_ids):
        reqs = sb.live_requests
        assert 1 <= len(reqs) <= self.max_batch, "batch size bound violated"
        self.run_lengths.append(len(node_ids))
        for r in reqs:
            assert r.idx + len(node_ids) <= len(r.sequence), \
                "run overruns a member's sequence"
            rem = [nid for nid, _ in r.sequence[r.idx:r.idx + len(node_ids)]]
            assert rem == list(node_ids), "run diverges from request sequence"
            self.executed.setdefault(r.rid, []).extend(node_ids)
        return super().execute_run(model, sb, node_ids)


def make_policy(kind, sla, max_batch):
    wls = list(WORKLOADS.values())
    if kind == "serial":
        return Serial()
    if kind == "graphb":
        return GraphBatching(0.010, max_batch=max_batch)
    if kind == "cellular":
        return CellularBatching(max_batch=max_batch)
    if kind == "lazyb":
        return LazyBatching(SlackPredictor.build(wls, PERF, sla),
                            max_batch=max_batch)
    return Oracle(OracleSlackPredictor(sla, PERF), max_batch=max_batch)


@settings(max_examples=15, deadline=None)
@given(
    kind=st.sampled_from(["serial", "graphb", "cellular", "lazyb", "oracle"]),
    wl_name=st.sampled_from(["resnet", "transformer"]),
    rate=st.sampled_from([50, 400, 1500]),
    seed=st.integers(0, 2 ** 16),
    max_batch=st.sampled_from([2, 8, 64]),
)
def test_policy_invariants(kind, wl_name, rate, seed, max_batch):
    wl = WORKLOADS[wl_name]
    trace = poisson_trace(wl, rate, duration=0.08, seed=seed).fresh()
    policy = make_policy(kind, sla=0.1, max_batch=max_batch)
    execu = CheckingExecutor(PERF, max_batch=max(1, max_batch))
    server = InferenceServer(policy, execu)
    stats = server.run(trace)

    # exactly-once completion
    assert len(stats.finished) == len(trace.requests)
    assert len({r.rid for r in stats.finished}) == len(trace.requests)
    for r in stats.finished:
        assert r.done
        assert r.t_finish >= r.arrival
        # executed exactly its node sequence, in order
        assert execu.executed[r.rid] == [nid for nid, _ in r.sequence]


@settings(max_examples=10, deadline=None)
@given(rate=st.sampled_from([200, 1200]), seed=st.integers(0, 2 ** 16))
def test_lazyb_admission_never_predicts_violation(rate, seed):
    """At every admission LazyB performed, the predictor's own model said
    no merged request would violate — re-check it post-hoc."""
    wl = WORKLOADS["transformer"]
    trace = poisson_trace(wl, rate, duration=0.05, seed=seed).fresh()
    pred = SlackPredictor.build([wl], PERF, sla_target=0.2)

    checked = []
    orig = pred.authorize

    def spy(ongoing, pending, now):
        ok = orig(ongoing, pending, now)
        if ok and ongoing:
            merged = list(ongoing) + list(pending)
            checked.append(all(pred.slack(r, merged, now) >= 0 for r in merged))
        return ok

    pred.authorize = spy
    policy = LazyBatching(pred, max_batch=64)
    InferenceServer(policy, SimExecutor(PERF)).run(trace)
    assert all(checked)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), window_ms=st.sampled_from([2, 20]))
def test_graphb_respects_window_and_size(seed, window_ms):
    """No batch is dispatched before the window elapses unless full."""
    wl = WORKLOADS["resnet"]
    window = window_ms * 1e-3
    max_batch = 4
    trace = poisson_trace(wl, 800, duration=0.05, seed=seed).fresh()

    dispatches = []

    class SpyGraphB(GraphBatching):
        def next_work(self, now):
            was_active = self.active is not None and self.active.size > 0
            work = super().next_work(now)
            if work is not None and not was_active:
                sb, _ = work
                dispatches.append((now, len(sb.live_requests),
                                   min(r.arrival for r in sb.live_requests)))
            return work

    policy = SpyGraphB(window, max_batch=max_batch)
    InferenceServer(policy, SimExecutor(PERF)).run(trace)
    assert dispatches
    for now, size, oldest in dispatches:
        assert size <= max_batch
        assert size == max_batch or now + 1e-9 >= oldest + window
