"""Quickstart: LazyBatching vs graph batching in 30 seconds.

Replays one Poisson inference trace (Transformer translation workload,
paper Table II) through four scheduling policies on the NPU latency model
and prints the latency / throughput / SLA comparison.

  PYTHONPATH=src python examples/quickstart.py [--rate 500] [--sla 0.1]
"""
import argparse

from repro.core.policies import GraphBatching, LazyBatching, Oracle, Serial
from repro.core.slack import OracleSlackPredictor, SlackPredictor
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import run_policy
from repro.serving.traffic import poisson_trace
from repro.serving.workload import get_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="transformer")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="query arrival rate (req/s)")
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--sla", type=float, default=0.100,
                    help="SLA target in seconds (paper default 100ms)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    wl = get_workload(args.workload)
    perf = NPUPerfModel()
    trace = poisson_trace(wl, args.rate, args.duration, seed=args.seed)
    predictor = SlackPredictor.build([wl], perf, args.sla)

    policies = [
        Serial(),
        GraphBatching(window=0.005),
        GraphBatching(window=0.025),
        GraphBatching(window=0.075),
        LazyBatching(predictor),
        Oracle(OracleSlackPredictor(args.sla, perf)),
    ]

    print(f"workload={wl.name}  rate={args.rate:g} req/s  "
          f"{len(trace)} requests  SLA={args.sla * 1e3:g}ms\n")
    hdr = (f"{'policy':<16}{'avg ms':>9}{'p99 ms':>9}{'thr r/s':>10}"
           f"{'SLA viol':>10}")
    print(hdr)
    print("-" * len(hdr))
    for pol in policies:
        stats = run_policy(pol, trace, perf)
        s = stats.summary(sla=args.sla)
        print(f"{s['policy']:<16}{s['avg_latency_ms']:>9.2f}"
              f"{s['p99_ms']:>9.2f}{s['throughput_rps']:>10.1f}"
              f"{s['sla_violation_rate'] * 100:>9.1f}%")


if __name__ == "__main__":
    main()
