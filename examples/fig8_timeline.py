"""Paper Fig. 8/10 walkthrough: watch the BatchTable preempt, catch up,
and merge on a synthetic 5-node graph.

Reproduces the paper's running example — Req1-2 batched at t=0, Req3-5
arriving mid-flight — and prints the per-node execution timeline plus the
stack state after every scheduling decision. Under graph batching Req3-5
wait for the whole graph; under LazyBatching they catch up and merge.

  PYTHONPATH=src python examples/fig8_timeline.py
"""
from repro.core.policies import GraphBatching, LazyBatching
from repro.core.request import Request
from repro.core.slack import SlackPredictor
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import InferenceServer, SimExecutor, run_label
from repro.serving.traffic import Trace
from repro.serving.workload import NodeDesc, Segment, Workload


def five_node_workload() -> Workload:
    """Five equal-cost nodes A..E (paper Fig. 8), ~1 time-unit each."""
    nodes = {}
    for nid in "ABCDE":
        # ~1 ms per node on the paper NPU (memory-bound weight streaming:
        # 360 MB / 360 GB/s)
        nodes[nid] = NodeDesc(nid, flops=1e6, weight_bytes=360e6,
                              act_bytes=1e3, m_rows=4, cell=False)
    return Workload("fig8", nodes, [Segment(tuple("ABCDE"))], kind="static")


class TimelineExecutor(SimExecutor):
    def __init__(self, perf, policy):
        super().__init__(perf)
        self.policy = policy
        self.events = []

    def execute_run(self, model, sb, node_ids):
        total, lats = super().execute_run(model, sb, node_ids)
        rids = sorted(r.rid for r in sb.live_requests)
        for node_id in node_ids:
            self.events.append((node_id, rids))
        stack = getattr(getattr(self.policy, "table", None), "stack", None)
        desc = ("  stack: " + " | ".join(
            f"{s.node_id}:{sorted(r.rid for r in s.live_requests)}"
            for s in stack)) if stack else ""
        print(f"  exec {run_label(node_ids)} for reqs {rids}{desc}")
        return total, lats


def run(policy_name: str):
    wl = five_node_workload()
    perf = NPUPerfModel()
    reqs = []
    for rid, arrival in [(1, 0.0), (2, 0.0), (3, 0.0021), (4, 0.0021),
                         (5, 0.0021)]:
        seq, pl, cl = wl.build_sequence(0, 0)
        r = Request(workload=wl, arrival=arrival, sequence=seq, rid=rid)
        reqs.append(r)
    trace = Trace(reqs, duration=0.02)
    if policy_name == "lazyb":
        pol = LazyBatching(SlackPredictor.build([wl], perf, 0.1), max_batch=8)
    else:
        pol = GraphBatching(window=0.001, max_batch=8)
    print(f"\n=== {policy_name} ===")
    ex = TimelineExecutor(perf, pol)
    stats = InferenceServer(pol, ex).run(trace)
    print(f"  node executions: {len(ex.events)}  "
          f"avg latency {stats.avg_latency * 1e3:.2f}ms")
    return len(ex.events), stats.avg_latency


def main():
    n_gb, lat_gb = run("graphb")
    n_lz, lat_lz = run("lazyb")
    print(f"\nLazyBatching merged mid-flight: {n_lz} node executions vs "
          f"{n_gb} for graph batching "
          f"({lat_gb / lat_lz:.1f}x lower average latency).")
    assert n_lz < n_gb, "lazy merging should reduce total node executions"


if __name__ == "__main__":
    main()
