"""Training example: train a ~100M-param llama-family model on the synthetic
token pipeline and verify the loss drops.

(Default is a scaled-down ~10M config so the example finishes in minutes on
this CPU container; pass --d-model 512 --layers 8 --steps 300 for the ~100M
run on real hardware.)

  PYTHONPATH=src python examples/train_small.py [--steps 60]
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import Model, RuntimeFlags
from repro.training import OptimizerConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(cfg, d_model=args.d_model,
                              num_layers=args.layers,
                              vocab_size=2048)
    model = Model(cfg, RuntimeFlags(dtype=jnp.float32))
    print(f"{cfg.name} variant: {cfg.param_count() / 1e6:.1f}M params")

    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    batch_size=args.batch))
    opt = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    state, log = train_loop(model, opt, iter(data), args.steps,
                            checkpoint_path=args.checkpoint, log_every=10)
    first, last = log.losses[0], log.losses[-1]
    print(f"\nloss {first:.3f} -> {last:.3f} in {log.wall[-1]:.0f}s")
    assert last < first - 0.5, "expected a clear loss reduction"
    print("training example OK")


if __name__ == "__main__":
    main()
