"""Policy comparison across traffic loads and workloads — a miniature of the
paper's Fig. 12/13 sweep, runnable in ~a minute.

Shows the paper's central claim: no single graph-batching time-window wins
across loads, while LazyBatching adapts (low latency at low load, graph-
batching-level throughput at high load).

  PYTHONPATH=src python examples/policy_comparison.py [--workload gnmt]
"""
import argparse

from repro.core.policies import GraphBatching, LazyBatching, Serial
from repro.core.slack import SlackPredictor
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import run_policy
from repro.serving.traffic import poisson_trace
from repro.serving.workload import get_workload


def make_policies(predictor):
    return [
        ("serial", lambda: Serial()),
        ("graphb(5ms)", lambda: GraphBatching(0.005)),
        ("graphb(25ms)", lambda: GraphBatching(0.025)),
        ("graphb(75ms)", lambda: GraphBatching(0.075)),
        ("lazyb", lambda: LazyBatching(predictor)),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="resnet",
                    help="resnet | gnmt | transformer | bert | ... or any "
                         "assigned arch id (e.g. llama3.2-1b)")
    ap.add_argument("--rates", default="16,250,1000")
    ap.add_argument("--duration", type=float, default=1.0)
    ap.add_argument("--sla", type=float, default=0.1)
    args = ap.parse_args()

    wl = get_workload(args.workload)
    perf = NPUPerfModel()
    predictor = SlackPredictor.build([wl], perf, args.sla)
    rates = [float(r) for r in args.rates.split(",")]

    for rate in rates:
        trace = poisson_trace(wl, rate, args.duration)
        print(f"\n=== {wl.name} @ {rate:g} req/s ({len(trace)} requests) ===")
        hdr = f"{'policy':<16}{'avg ms':>9}{'p99 ms':>9}{'SLA viol':>10}"
        print(hdr)
        best = {}
        for name, mk in make_policies(predictor):
            stats = run_policy(mk(), trace, perf)
            s = stats.summary(sla=args.sla)
            best[name] = s["avg_latency_ms"]
            print(f"{name:<16}{s['avg_latency_ms']:>9.2f}{s['p99_ms']:>9.2f}"
                  f"{s['sla_violation_rate'] * 100:>9.1f}%")
        gb = min(v for k, v in best.items() if k.startswith("graphb"))
        print(f"-> lazyb vs best graphb: {gb / best['lazyb']:.2f}x "
              f"lower average latency")


if __name__ == "__main__":
    main()
