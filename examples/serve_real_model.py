"""End-to-end driver: LazyBatching serving a REAL model with batched requests.

Builds a reduced llama-family model, generates a Poisson request trace, and
serves it ONLINE through the ``ServingSession`` front-end: requests are
submitted with streaming callbacks, the LazyBatching scheduler
preempts/merges sub-batches at layer boundaries, and every committed node
run executes actual jitted layer functions on the JAX engine.

Correctness is verified, not assumed:
  * every request's *streamed* tokens (fired from run boundaries) must be
    bit-identical to the engine's batch ``execute_run`` results, and
  * both must match an isolated (no batching, no preemption) reference
    generation of the same prompt — lazy batching must not change results.

  PYTHONPATH=src python examples/serve_real_model.py \
      [--arch llama3.2-1b] [--n 12] [--rate 20]
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core.policies import LazyBatching
from repro.core.slack import SlackPredictor
from repro.serving.engine import JaxEngine
from repro.serving.npu_model import NPUPerfModel, TPU_V5E
from repro.serving.session import HandleState, ServingSession
from repro.serving.workload import fixed_length, from_model_config, LengthDist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--n", type=int, default=12, help="number of requests")
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--sla", type=float, default=60.0,
                    help="SLA target (seconds — CPU wall-clock is slow)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(args.seed)

    # request population: short prompts, a few decode steps each
    prompt_dist = LengthDist((6, 8, 10, 12), (0.25, 0.25, 0.25, 0.25))
    decode_dist = LengthDist((2, 3, 4, 5), (0.25, 0.25, 0.25, 0.25))
    wl = from_model_config(cfg, prompt_dist=prompt_dist,
                           decode_dist=decode_dist)

    engine = JaxEngine(cfg, max_len=64)
    predictor = SlackPredictor.build([wl], NPUPerfModel(TPU_V5E), args.sla)
    policy = LazyBatching(predictor, max_batch=args.max_batch)
    session = ServingSession(policy, engine, seed=args.seed)

    streamed = {}                       # rid -> tokens seen via on_token

    def on_token(handle, token):
        streamed.setdefault(handle.request.rid, []).append(token)

    handles, prompts = [], {}
    t = 0.0
    for _ in range(args.n):
        t += rng.exponential(1.0 / args.rate)
        r = wl.sample_request(rng, t)
        prompt = rng.integers(2, cfg.vocab_size, size=r.prompt_len)
        prompts[r.rid] = prompt
        handles.append(session.submit(r, prompt_tokens=prompt,
                                      on_token=on_token))
    session.duration = t

    print(f"serving {args.n} requests on reduced {args.arch} "
          f"({cfg.param_count() / 1e6:.1f}M params), "
          f"max_batch={args.max_batch} ...")
    stats = session.drain()

    s = stats.summary()
    print(f"completed {s['completed']}/{args.n}  "
          f"avg latency {s['avg_latency_ms']:.0f}ms (CPU wall-clock)  "
          f"nodes executed {engine.nodes_executed}  "
          f"preemptions {policy.n_preemptions}")
    assert s["completed"] == args.n
    assert all(h.state is HandleState.DONE for h in handles)

    # ---- verify: streamed == batch-executed == isolated reference ------
    print("verifying streamed tokens against batch results and an "
          "isolated (unbatched) reference ...")
    ref_engine = JaxEngine(cfg, max_len=64)     # same seed -> same params
    mismatches = 0
    for h in handles:
        r = h.request
        got = engine.states[r.rid].generated[:r.decode_len]
        assert streamed[r.rid][:r.decode_len] == got == h.tokens[:r.decode_len], \
            f"rid={r.rid}: streamed tokens diverge from batch execute_run"
        ref = _reference_generate(ref_engine, wl, prompts[r.rid],
                                  r.decode_len)
        if got != ref:
            mismatches += 1
            print(f"  rid={r.rid}: engine {got} != reference {ref}")
    if mismatches:
        raise SystemExit(f"{mismatches} requests diverged from reference!")
    print(f"all {args.n} generations match the unbatched reference — "
          "lazy batching preserved results exactly.")


def _reference_generate(engine: JaxEngine, wl, prompt, n_tokens: int):
    """Generate in isolation through the same engine (batch of 1, no
    preemption): the ground truth LazyBatching must reproduce."""
    rng = np.random.default_rng(123)
    req = wl.sample_request(rng, 0.0)
    # rebuild the node sequence for this exact prompt/decode length
    seq, prefix_len, cycle_len = wl.build_sequence(len(prompt), n_tokens)
    req.sequence, req.prefix_len, req.cycle_len = seq, prefix_len, cycle_len
    req.prompt_len, req.decode_len = len(prompt), n_tokens
    engine.register(req, prompt)
    from repro.core.request import SubBatch
    sb = SubBatch([req])
    while not req.done:
        engine.execute("m", sb, req.next_node_id)
        sb.advance(0.0)
    return engine.states[req.rid].generated[:n_tokens]


if __name__ == "__main__":
    main()
