"""Shared harness for the per-figure benchmarks.

Every benchmark module exposes ``run(quick: bool) -> dict`` returning a
JSON-serializable record; ``benchmarks.run`` executes them all and prints
the consolidated report (the EXPERIMENTS.md §Paper-validation source).
"""
from __future__ import annotations

import numpy as np

from repro.core.policies import (CellularBatching, GraphBatching,
                                 LazyBatching, Oracle, Serial)
from repro.core.slack import OracleSlackPredictor, SlackPredictor
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import run_policy
from repro.serving.traffic import poisson_trace
from repro.serving.workload import get_workload

DEFAULT_SLA = 0.100           # 100 ms (paper §VI)
WINDOWS = (0.005, 0.025, 0.050, 0.075, 0.095)    # GraphB(N) sweep (Fig. 12)


def make_policy(kind: str, wl_list, perf, sla=DEFAULT_SLA, max_batch=64,
                window=None):
    if kind == "serial":
        return Serial()
    if kind == "graphb":
        return GraphBatching(window=window, max_batch=max_batch)
    if kind == "cellular":
        return CellularBatching(max_batch=max_batch)
    if kind == "lazyb":
        pred = SlackPredictor.build(wl_list, perf, sla)
        return LazyBatching(pred, max_batch=max_batch)
    if kind == "oracle":
        return Oracle(OracleSlackPredictor(sla, perf), max_batch=max_batch)
    raise KeyError(kind)


def sweep(workload_name: str, rates, *, duration=1.0, seeds=(0, 1, 2),
          sla=DEFAULT_SLA, policies=None, max_batch=64,
          windows=WINDOWS, perf=None):
    """Run every policy over every (rate, seed); returns nested dict
    results[rate][policy_name] = averaged summary."""
    wl = get_workload(workload_name)
    perf = perf or NPUPerfModel()
    if policies is None:
        policies = (["serial"]
                    + [("graphb", w) for w in windows]
                    + ["lazyb", "oracle"])
    out = {}
    for rate in rates:
        per_policy = {}
        for pol in policies:
            kind, window = (pol if isinstance(pol, tuple) else (pol, None))
            sums = []
            for seed in seeds:
                trace = poisson_trace(wl, rate, duration, seed=seed)
                p = make_policy(kind, [wl], perf, sla=sla,
                                max_batch=max_batch, window=window)
                stats = run_policy(p, trace, perf)
                sums.append(stats.summary(sla=sla))
            name = sums[0]["policy"]
            per_policy[name] = {
                k: float(np.mean([s[k] for s in sums]))
                for k in sums[0] if k != "policy"}
            per_policy[name]["policy"] = name
        out[rate] = per_policy
    return out


def best_graphb(per_policy: dict, metric="avg_latency_ms", minimize=True):
    """Best-performing graph-batching config for a metric (the paper's
    comparison baseline)."""
    cands = {k: v for k, v in per_policy.items() if k.startswith("graphb")}
    pick = min if minimize else max
    name = pick(cands, key=lambda k: cands[k][metric])
    return name, cands[name]


def fmt_table(rows, headers) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    def line(vals):
        return "  ".join(str(v).rjust(w) for v, w in zip(vals, widths))
    return "\n".join([line(headers), line(["-" * w for w in widths])]
                     + [line(r) for r in rows])
