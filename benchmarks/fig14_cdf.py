"""Fig. 14: latency CDF under high load — tail-latency reduction.

Claim: p99 of LazyBatching is far below the best graph batching (e.g. 54 vs
123 ms for Transformer at 1K req/s).
"""
import numpy as np

from repro.core.policies import GraphBatching, LazyBatching
from repro.core.slack import SlackPredictor
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import run_policy
from repro.serving.traffic import poisson_trace
from repro.serving.workload import get_workload
from .common import DEFAULT_SLA, WINDOWS, fmt_table


def run(quick: bool = True) -> dict:
    perf = NPUPerfModel()
    dur = 0.5 if quick else 2.0
    rec, rows = {}, []
    for wname in ("resnet", "gnmt", "transformer"):
        wl = get_workload(wname)
        pred = SlackPredictor.build([wl], perf, DEFAULT_SLA)
        trace = poisson_trace(wl, 1000.0, dur, seed=0)
        lazy = run_policy(LazyBatching(pred), trace, perf)
        best = None
        for w in WINDOWS:
            st = run_policy(GraphBatching(window=w), trace, perf)
            if best is None or st.percentile(99) < best.percentile(99):
                best = st
        rec[wname] = {
            "lazyb_p50": lazy.percentile(50) * 1e3,
            "lazyb_p99": lazy.percentile(99) * 1e3,
            "graphb_p50": best.percentile(50) * 1e3,
            "graphb_p99": best.percentile(99) * 1e3,
        }
        rows.append([wname,
                     f"{rec[wname]['lazyb_p50']:.1f}",
                     f"{rec[wname]['lazyb_p99']:.1f}",
                     f"{rec[wname]['graphb_p50']:.1f}",
                     f"{rec[wname]['graphb_p99']:.1f}",
                     f"{rec[wname]['graphb_p99'] / rec[wname]['lazyb_p99']:.1f}x"])
    print("\n# Fig. 14 — tail latency at 1K req/s (best graphb by p99)")
    print(fmt_table(rows, ["workload", "lazy p50", "lazy p99",
                           "graphb p50", "graphb p99", "p99 gain"]))
    return rec
