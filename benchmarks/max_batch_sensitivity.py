"""§VI-C: model-allowed maximum batch size sensitivity.

Paper: with graph batching max batch 16 / 32 (instead of 64), LazyBatching
still achieves 12x / 14x average-config latency reduction and 1.3x
throughput.
"""
import numpy as np

from .common import best_graphb, fmt_table, sweep


def run(quick: bool = True) -> dict:
    dur = 0.5 if quick else 2.0
    rec, rows = {}, []
    for mb in (16, 32, 64):
        res = sweep("transformer", [1000], duration=dur,
                    seeds=(0,) if quick else (0, 1, 2), max_batch=mb)
        pp = res[1000]
        lz = pp["lazyb"]["avg_latency_ms"]
        _, bg = best_graphb(pp)
        allgb = float(np.mean([v["avg_latency_ms"] for k, v in pp.items()
                               if k.startswith("graphb")]))
        rec[mb] = {"vs_best": bg["avg_latency_ms"] / lz,
                   "vs_avg": allgb / lz}
        rows.append([mb, f"{bg['avg_latency_ms'] / lz:.1f}x",
                     f"{allgb / lz:.1f}x"])
    print("\n# max-batch sensitivity (Transformer @1K req/s)")
    print(fmt_table(rows, ["max batch", "lazyb vs best gb",
                           "lazyb vs avg gb"]))
    return rec
