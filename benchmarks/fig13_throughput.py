"""Fig. 13: throughput per query-arrival rate.

Claim: LazyBatching matches or beats the throughput-optimized graph
batching (1.1x / 1.3x / 1.2x for ResNet / GNMT / Transformer) — here
measured as completed requests per second over the trace window including
drain, so policies that stall requests score lower.
"""
import numpy as np

from .common import best_graphb, fmt_table, sweep

WORKLOADS = ("resnet", "gnmt", "transformer")


def run(quick: bool = True) -> dict:
    rates = [250, 1000] if quick else [250, 500, 1000, 2000]
    dur = 0.5 if quick else 2.0
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    rec, rows = {}, []
    for wname in WORKLOADS:
        res = sweep(wname, rates, duration=dur, seeds=seeds)
        gains = []
        for rate in rates:
            pp = res[rate]
            lz = pp["lazyb"]["throughput_rps"]
            bg_name, bg = best_graphb(pp, "throughput_rps", minimize=False)
            gains.append(lz / bg["throughput_rps"])
            rows.append([wname, rate, f"{pp['serial']['throughput_rps']:.0f}",
                         f"{bg['throughput_rps']:.0f}({bg_name})",
                         f"{lz:.0f}", f"{pp['oracle']['throughput_rps']:.0f}"])
        rec[wname] = {"gain_vs_best_graphb": float(np.mean(gains))}
    print("\n# Fig. 13 — throughput (completed r/s) per arrival rate")
    print(fmt_table(rows, ["workload", "rate", "serial", "best graphb",
                           "lazyb", "oracle"]))
    for w, g in rec.items():
        print(f"{w}: lazyb {g['gain_vs_best_graphb']:.2f}x vs best graphb "
              f"(paper: >= ~1.1-1.3x)")
    return rec
