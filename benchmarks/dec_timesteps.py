"""§VI-C sensitivity: dec_timesteps (predicted unrolled sequence length).

Claim: a small dec_timesteps (optimistic latency prediction -> inflated
slack) causes SLA violations (paper: 36% for Transformer at N=16% coverage
/ 10 steps with a 60 ms SLA); a sufficiently overprovisioned value (N=90%)
achieves ~zero.
"""
from repro.core.policies import LazyBatching
from repro.core.slack import SlackPredictor
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import run_policy
from repro.serving.traffic import poisson_trace
from repro.serving.workload import get_workload
from .common import fmt_table


def run(quick: bool = True) -> dict:
    perf = NPUPerfModel()
    wl = get_workload("transformer")
    sla = 0.060
    dur = 0.5 if quick else 2.0
    # heavier than fig15's 1K req/s: dec_timesteps mispredictions only bite
    # when the server is congested enough that over-admission backs up
    trace = poisson_trace(wl, 2500.0, dur, seed=0)
    rec, rows = {}, []
    for cov in (0.16, 0.50, 0.90, 0.99):
        pred = SlackPredictor.build([wl], perf, sla, coverage=cov)
        dt = pred.dec_timesteps[wl.name]
        stats = run_policy(LazyBatching(pred), trace, perf)
        v = stats.sla_violation_rate(sla)
        rec[cov] = {"dec_timesteps": dt, "violation_rate": v,
                    "avg_ms": stats.avg_latency * 1e3}
        rows.append([f"{cov * 100:.0f}%", dt, f"{v * 100:.1f}%",
                     f"{stats.avg_latency * 1e3:.1f}"])
    print("\n# dec_timesteps sensitivity (Transformer, SLA 60 ms, 2.5K req/s)")
    print(fmt_table(rows, ["coverage N", "dec_timesteps", "SLA viol",
                           "avg ms"]))
    worse = rec[0.16]["violation_rate"] >= rec[0.90]["violation_rate"]
    print(f"optimistic (N=16%) >= conservative (N=90%) violations: {worse}")
    return {"by_coverage": {f"{c:g}": v for c, v in rec.items()},
            "optimistic_worse": worse}
