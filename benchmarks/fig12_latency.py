"""Fig. 12: average latency per query-arrival rate, all policies.

Headline claim: LazyBatching gives 5.3x / 2.7x / 2.5x lower latency than the
best-performing graph batching for ResNet / GNMT / Transformer (and ~15x on
average across all graph-batching configs).
"""
import numpy as np

from .common import best_graphb, fmt_table, sweep

WORKLOADS = ("resnet", "gnmt", "transformer")


def run(quick: bool = True) -> dict:
    rates = [16, 250, 1000] if quick else [16, 100, 250, 500, 1000, 2000]
    dur = 0.5 if quick else 2.0
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    rec, rows = {}, []
    for wname in WORKLOADS:
        res = sweep(wname, rates, duration=dur, seeds=seeds)
        gains_best, gains_all = [], []
        for rate in rates:
            pp = res[rate]
            lz = pp["lazyb"]["avg_latency_ms"]
            bg_name, bg = best_graphb(pp)
            gains_best.append(bg["avg_latency_ms"] / lz)
            all_gb = [v["avg_latency_ms"] for k, v in pp.items()
                      if k.startswith("graphb")]
            gains_all.append(float(np.mean(all_gb)) / lz)
            rows.append([wname, rate, f"{pp['serial']['avg_latency_ms']:.2f}",
                         f"{bg['avg_latency_ms']:.2f}({bg_name})",
                         f"{lz:.2f}", f"{pp['oracle']['avg_latency_ms']:.2f}"])
        rec[wname] = {
            "gain_vs_best_graphb": float(np.mean(gains_best)),
            "gain_vs_avg_graphb": float(np.mean(gains_all)),
        }
    print("\n# Fig. 12 — average latency (ms) per arrival rate")
    print(fmt_table(rows, ["workload", "rate", "serial", "best graphb",
                           "lazyb", "oracle"]))
    for w, g in rec.items():
        print(f"{w}: lazyb {g['gain_vs_best_graphb']:.1f}x vs best graphb, "
              f"{g['gain_vs_avg_graphb']:.1f}x vs average graphb config "
              f"(paper: 5.3/2.7/2.5x best; ~15x avg)")
    return rec
