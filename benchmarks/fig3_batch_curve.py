"""Fig. 3: throughput and latency of pre-formed batches vs batch size.

Batched inputs are assumed already formed (no collection wait); shows
throughput rising then saturating (~16 for ResNet) while per-input latency
falls — the tradeoff curve that motivates bounded max batch size.
"""
from repro.serving.npu_model import NPUPerfModel
from repro.serving.workload import get_workload
from .common import fmt_table


def run(quick: bool = True) -> dict:
    perf = NPUPerfModel()
    wl = get_workload("resnet")
    sizes = [1, 2, 4, 8, 16, 32, 64]
    rows, rec = [], {}
    for n in sizes:
        lat = sum(perf.node_latency(wl.nodes[nid], [ctx] * n)
                  for nid, ctx in wl.build_sequence(0, 0)[0])
        thr = n / lat
        rec[n] = {"latency_ms": lat * 1e3, "throughput_rps": thr,
                  "latency_avg_ms": lat / n * 1e3}
        rows.append([n, f"{lat * 1e3:.2f}", f"{lat / n * 1e3:.3f}",
                     f"{thr:.0f}"])
    print("\n# Fig. 3 — ResNet batching tradeoff (pre-formed batches)")
    print(fmt_table(rows, ["batch", "lat(all) ms", "lat(avg) ms", "thr r/s"]))
    # saturation check: going 16 -> 64 must help < 2x (curve levels out)
    sat = rec[64]["throughput_rps"] / rec[16]["throughput_rps"]
    mono = all(rec[sizes[i + 1]]["throughput_rps"]
               >= rec[sizes[i]]["throughput_rps"] for i in range(len(sizes) - 1))
    print(f"throughput monotone: {mono}; 16->64 gain {sat:.2f}x (saturating)")
    return {"curve": rec, "monotone": mono, "sat_gain_16_64": sat}
