"""Roofline report: renders the §Roofline table from the dry-run/probe
JSON records under results/ (produced by ``repro.launch.dryrun`` and
``repro.launch.roofline``). Skips gracefully when the sweep has not run.
"""
import json
import os


def run(quick: bool = True) -> dict:
    rdir = "results/roofline"
    if not os.path.isdir(rdir):
        print("\n# Roofline — results/roofline not found; run "
              "`python -m repro.launch.roofline --all` first (skipped)")
        return {"skipped": True}
    from repro.launch.roofline import render_table
    recs = []
    for fn in sorted(os.listdir(rdir)):
        if fn.endswith(".json"):
            with open(os.path.join(rdir, fn)) as f:
                r = json.load(f)
            if "error" not in r:
                recs.append(r)
    print(f"\n# Roofline — {len(recs)} (arch × shape) baselines")
    print(render_table(recs))
    doms = {}
    for r in recs:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print("dominant-term distribution:", doms)
    return {"n": len(recs), "dominant_distribution": doms}
