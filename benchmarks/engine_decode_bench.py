"""Per-token decode dispatch cost: persistent slot arena vs seed restacking.

The seed engine restacked every layer's full ``max_len`` KV cache across
the merged sub-batch on EVERY decode node dispatch (an
O(B x max_len x d_model) copy per layer per token); the arena engine keeps
caches device-resident in per-layer slot arenas and gathers/scatters rows
in-jit. This benchmark drives both engines through identical merged decode
cycles at batch 8 and reports steady-state wall-clock per generated token
(compile-warmup tokens excluded). The acceptance bar for the arena PR is
>= 2x.

  PYTHONPATH=src python benchmarks/engine_decode_bench.py \
      [--arch llama3.2-1b] [--batch 8] [--max-len 256] [--tokens 24]
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.core.request import SubBatch
from repro.serving.engine import JaxEngine
from repro.serving.workload import LengthDist, from_model_config


def _build_batch(engine, wl, cfg, batch, prompt_len, decode_len, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(batch):
        r = wl.sample_request(rng, 0.0)
        seq, prefix_len, cycle_len = wl.build_sequence(prompt_len, decode_len)
        r.sequence, r.prefix_len, r.cycle_len = seq, prefix_len, cycle_len
        r.prompt_len, r.decode_len = prompt_len, decode_len
        engine.register(r, rng.integers(2, cfg.vocab_size, size=prompt_len))
        reqs.append(r)
    return reqs


def bench_mode(mode, cfg, wl, *, batch, max_len, tokens, warmup):
    engine = JaxEngine(cfg, max_len=max_len, cache_mode=mode,
                       n_slots=max(batch, 8))
    reqs = _build_batch(engine, wl, cfg, batch, prompt_len=16,
                        decode_len=tokens + warmup)
    # prefill each request to completion of its prefix (emb + P-nodes)
    n_prefill = 1 + len(engine.kinds)
    for r in reqs:
        sb = SubBatch([r])
        for _ in range(n_prefill):
            engine.execute(sb, r.next_node_id)
            sb.advance(0.0)
    # merged decode: one sub-batch, lockstep cycles of D-nodes + head
    sb = SubBatch(list(reqs))
    per_token = []
    for t in range(tokens + warmup):
        t0 = time.perf_counter()
        for _ in range(len(wl.cycle_ids())):
            engine.execute(sb, sb.node_id)
            sb.advance(0.0)
        per_token.append(time.perf_counter() - t0)
    steady = per_token[warmup:]
    return float(np.mean(steady)), float(np.min(steady))


def run(quick: bool = True) -> dict:
    args = argparse.Namespace(arch="llama3.2-1b", batch=8, max_len=256,
                              tokens=12 if quick else 24, warmup=3)
    return _run(args)


def _run(args) -> dict:
    cfg = get_config(args.arch).reduced()
    wl = from_model_config(cfg,
                          prompt_dist=LengthDist((16,), (1.0,)),
                          decode_dist=LengthDist((4,), (1.0,)))
    rec = {"arch": args.arch, "batch": args.batch, "max_len": args.max_len}
    for mode in ("legacy", "arena"):
        mean_s, min_s = bench_mode(mode, cfg, wl, batch=args.batch,
                                   max_len=args.max_len, tokens=args.tokens,
                                   warmup=args.warmup)
        rec[mode] = {"mean_ms_per_token": mean_s * 1e3,
                     "min_ms_per_token": min_s * 1e3}
        print(f"{mode:>7}: {mean_s * 1e3:8.2f} ms/token mean "
              f"({min_s * 1e3:.2f} min) over {args.tokens} steady tokens")
    speedup = (rec["legacy"]["mean_ms_per_token"]
               / rec["arena"]["mean_ms_per_token"])
    rec["speedup"] = speedup
    print(f"speedup: {speedup:.1f}x (arena vs seed restacking, "
          f"batch {args.batch}, max_len {args.max_len})")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=24,
                    help="steady-state tokens timed per mode")
    ap.add_argument("--warmup", type=int, default=3,
                    help="compile-warmup tokens excluded from timing")
    _run(ap.parse_args())


if __name__ == "__main__":
    main()
