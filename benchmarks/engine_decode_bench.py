"""Per-token decode dispatch cost: legacy restacking vs arena vs fused runs.

Three engine dispatch modes over identical merged decode cycles:

  * ``legacy``  — the seed path: per-request padded caches restacked across
                  the sub-batch on EVERY decode node dispatch,
  * ``arena``   — PR 1: persistent device-resident slot arenas, but still
                  one blocking dispatch per node (~L+2 Python→device
                  round-trips per token),
  * ``fused``   — this PR: the committed decode cycle ``D0..D{L-1}+head``
                  executes as ONE jitted scanned megastep over the stacked
                  span params/arenas, async inside the run, synced only at
                  the run boundary.

Reports steady-state wall-clock per generated token (a full warmup pass
over an identical workload runs first, so every jit/bucket is compiled
before timing), verifies the generated tokens are BIT-EXACT across all
three modes, and emits machine-readable results to
``BENCH_engine_decode.json`` so the perf trajectory is tracked across PRs
(``--smoke`` runs skip the artifact). The acceptance bar for this PR is
fused >= 3x over arena at batch 8.

  PYTHONPATH=src python benchmarks/engine_decode_bench.py \
      [--arch llama3.2-1b] [--batch 8] [--max-len 256] [--tokens 24]
      [--smoke]           # tiny config + few tokens (CI rot guard)
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.configs import get_config
from repro.core.request import SubBatch
from repro.serving.engine import JaxEngine
from repro.serving.workload import LengthDist, from_model_config

MODES = ("legacy", "arena", "fused")


def _build_batch(engine, wl, cfg, batch, prompt_len, decode_len, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(batch):
        r = wl.sample_request(rng, 0.0)
        seq, prefix_len, cycle_len = wl.build_sequence(prompt_len, decode_len)
        r.sequence, r.prefix_len, r.cycle_len = seq, prefix_len, cycle_len
        r.prompt_len, r.decode_len = prompt_len, decode_len
        engine.register(r, rng.integers(2, cfg.vocab_size, size=prompt_len))
        reqs.append(r)
    return reqs


def _drive(engine, wl, reqs, mode, tokens):
    """Prefill then decode ``tokens`` merged cycles; per-cycle wall-clock."""
    if mode == "fused":
        # committed prefill run per request (bucketed/batched internally)
        for r in reqs:
            sb = SubBatch([r])
            run = sb.run_nodes(stop_before={"D0"})
            engine.execute_run("m", sb, run)
            sb.advance_n(len(run), 0.0)
    else:
        n_prefill = 1 + len(engine.kinds)
        for r in reqs:
            sb = SubBatch([r])
            for _ in range(n_prefill):
                engine.execute("m", sb, r.next_node_id)
                sb.advance(0.0)
    # merged decode: one sub-batch, lockstep cycles of D-nodes + head
    sb = SubBatch(list(reqs))
    per_token = []
    for t in range(tokens):
        t0 = time.perf_counter()
        if mode == "fused":
            # one committed run per decode cycle (iteration-level boundary)
            run = sb.run_nodes(stop_after={"head"})
            engine.execute_run("m", sb, run)
            sb.advance_n(len(run), 0.0)
        else:
            for _ in range(len(wl.cycle_ids())):
                engine.execute("m", sb, sb.node_id)
                sb.advance(0.0)
        per_token.append(time.perf_counter() - t0)
    return per_token


def bench_mode(mode, cfg, wl, *, batch, max_len, tokens):
    """Steady-state dispatch cost: a full warmup pass over an identical
    workload first compiles every jit the timed pass will hit (incl. every
    context bucket a growing decode crosses), then a fresh same-seed batch
    on the SAME engine (shared jit cache) is timed compile-free."""
    cache_mode = "legacy" if mode == "legacy" else "arena"
    engine = JaxEngine(cfg, max_len=max_len, cache_mode=cache_mode,
                       n_slots=max(batch, 8), fused=(mode == "fused"))
    warm = _build_batch(engine, wl, cfg, batch, prompt_len=16,
                        decode_len=tokens)
    _drive(engine, wl, warm, mode, tokens)
    reqs = _build_batch(engine, wl, cfg, batch, prompt_len=16,
                        decode_len=tokens)
    steady = _drive(engine, wl, reqs, mode, tokens)
    toks = [engine.states[r.rid].generated for r in reqs]
    # median is the headline number: robust to scheduler noise on shared
    # CPU runners (mean/min recorded alongside)
    return (float(np.median(steady)), float(np.mean(steady)),
            float(np.min(steady)), toks)


def run(quick: bool = True) -> dict:
    # programmatic suite entry: never writes the tracked artifact (quick
    # configs would clobber the committed 24-token numbers)
    args = argparse.Namespace(arch="llama3.2-1b", batch=8, max_len=256,
                              tokens=12 if quick else 24,
                              smoke=False, out=None, write=False)
    return _run(args)


def _run(args) -> dict:
    import jax
    cfg = get_config(args.arch).reduced()
    if args.smoke:
        cfg = dataclasses.replace(cfg, d_model=64, d_ff=128, vocab_size=256,
                                  num_prefix_embeddings=0)
    wl = from_model_config(cfg,
                          prompt_dist=LengthDist((16,), (1.0,)),
                          decode_dist=LengthDist((4,), (1.0,)))
    rec = {"arch": args.arch, "batch": args.batch, "max_len": args.max_len,
           "tokens": args.tokens, "smoke": bool(args.smoke),
           "backend": jax.default_backend()}
    all_toks = {}
    for mode in MODES:
        med_s, mean_s, min_s, toks = bench_mode(
            mode, cfg, wl, batch=args.batch, max_len=args.max_len,
            tokens=args.tokens)
        all_toks[mode] = toks
        rec[mode] = {"median_ms_per_token": med_s * 1e3,
                     "mean_ms_per_token": mean_s * 1e3,
                     "min_ms_per_token": min_s * 1e3}
        print(f"{mode:>7}: {med_s * 1e3:8.2f} ms/token median "
              f"({mean_s * 1e3:.2f} mean, {min_s * 1e3:.2f} min) "
              f"over {args.tokens} steady tokens")
    rec["tokens_bitexact"] = (all_toks["legacy"] == all_toks["arena"]
                              == all_toks["fused"])
    assert rec["tokens_bitexact"], \
        "generated tokens diverged across dispatch modes"
    rec["speedup_arena_vs_legacy"] = (rec["legacy"]["median_ms_per_token"]
                                      / rec["arena"]["median_ms_per_token"])
    rec["speedup_fused_vs_arena"] = (rec["arena"]["median_ms_per_token"]
                                     / rec["fused"]["median_ms_per_token"])
    print(f"tokens bit-exact across modes: {rec['tokens_bitexact']}")
    print(f"speedup: {rec['speedup_arena_vs_legacy']:.1f}x arena vs legacy, "
          f"{rec['speedup_fused_vs_arena']:.1f}x fused vs arena "
          f"(batch {args.batch}, max_len {args.max_len})")
    if args.out:
        out = Path(args.out)
    elif getattr(args, "write", True) and not args.smoke:
        # full CLI runs refresh the tracked artifact; smoke/programmatic
        # runs must not clobber it
        out = Path(__file__).resolve().parent.parent / "BENCH_engine_decode.json"
    else:
        out = None
    if out is not None:
        out.write_text(json.dumps(rec, indent=2) + "\n")
        print(f"wrote {out}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=24,
                    help="steady-state tokens timed per mode (a full "
                         "warmup pass of the same length runs first)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + short run (CI rot guard)")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default: repo root)")
    args = ap.parse_args()
    if args.smoke:
        args.batch = min(args.batch, 4)
        args.max_len = min(args.max_len, 64)
        args.tokens = min(args.tokens, 4)
    _run(args)


if __name__ == "__main__":
    main()
