"""Per-token decode dispatch cost: legacy restacking vs arena vs fused runs.

Three engine dispatch modes over identical merged decode cycles:

  * ``legacy``  — the seed path: per-request padded caches restacked across
                  the sub-batch on EVERY decode node dispatch,
  * ``arena``   — PR 1: persistent device-resident slot arenas, but still
                  one blocking dispatch per node (~L+2 Python→device
                  round-trips per token),
  * ``fused``   — this PR: the committed decode cycle ``D0..D{L-1}+head``
                  executes as ONE jitted scanned megastep over the stacked
                  span params/arenas, async inside the run, synced only at
                  the run boundary.

Reports steady-state wall-clock per generated token (a full warmup pass
over an identical workload runs first, so every jit/bucket is compiled
before timing), verifies the generated tokens are BIT-EXACT across all
three modes, and emits machine-readable results to
``BENCH_engine_decode.json`` so the perf trajectory is tracked across PRs
(``--smoke`` runs skip the artifact). The acceptance bar for this PR is
fused >= 3x over arena at batch 8.

Also times the paged arena's **shrink/compact** reclamation (burst →
drain → compact live slots + halve), so the cost of returning device
memory is tracked next to the decode hot path it must never sit on.

``--baseline PATH`` compares this run's per-mode median ms/token against
a previously committed artifact and exits non-zero when any mode
regressed by more than ``--tolerance`` (default 20%) — the CI perf gate.

  PYTHONPATH=src python benchmarks/engine_decode_bench.py \
      [--arch llama3.2-1b] [--batch 8] [--max-len 256] [--tokens 24]
      [--smoke]           # tiny config + few tokens (CI rot guard)
      [--baseline BENCH_engine_decode.json] [--tolerance 0.2]
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.configs import get_config
from repro.core.request import SubBatch
from repro.serving.engine import JaxEngine
from repro.serving.workload import LengthDist, from_model_config

MODES = ("legacy", "arena", "fused")


def _build_batch(engine, wl, cfg, batch, prompt_len, decode_len, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(batch):
        r = wl.sample_request(rng, 0.0)
        seq, prefix_len, cycle_len = wl.build_sequence(prompt_len, decode_len)
        r.sequence, r.prefix_len, r.cycle_len = seq, prefix_len, cycle_len
        r.prompt_len, r.decode_len = prompt_len, decode_len
        engine.register(r, rng.integers(2, cfg.vocab_size, size=prompt_len))
        reqs.append(r)
    return reqs


def _drive(engine, wl, reqs, mode, tokens):
    """Prefill then decode ``tokens`` merged cycles; per-cycle wall-clock."""
    if mode == "fused":
        # committed prefill run per request (bucketed/batched internally)
        for r in reqs:
            sb = SubBatch([r])
            run = sb.run_nodes(stop_before={"D0"})
            engine.execute_run("m", sb, run)
            sb.advance_n(len(run), 0.0)
    else:
        n_prefill = 1 + len(engine.kinds)
        for r in reqs:
            sb = SubBatch([r])
            for _ in range(n_prefill):
                engine.execute("m", sb, r.next_node_id)
                sb.advance(0.0)
    # merged decode: one sub-batch, lockstep cycles of D-nodes + head
    sb = SubBatch(list(reqs))
    per_token = []
    for t in range(tokens):
        t0 = time.perf_counter()
        if mode == "fused":
            # one committed run per decode cycle (iteration-level boundary)
            run = sb.run_nodes(stop_after={"head"})
            engine.execute_run("m", sb, run)
            sb.advance_n(len(run), 0.0)
        else:
            for _ in range(len(wl.cycle_ids())):
                engine.execute("m", sb, sb.node_id)
                sb.advance(0.0)
        per_token.append(time.perf_counter() - t0)
    return per_token


def bench_mode(mode, cfg, wl, *, batch, max_len, tokens):
    """Steady-state dispatch cost: a full warmup pass over an identical
    workload first compiles every jit the timed pass will hit (incl. every
    context bucket a growing decode crosses), then a fresh same-seed batch
    on the SAME engine (shared jit cache) is timed compile-free."""
    cache_mode = "legacy" if mode == "legacy" else "arena"
    engine = JaxEngine(cfg, max_len=max_len, cache_mode=cache_mode,
                       n_slots=max(batch, 8), fused=(mode == "fused"))
    warm = _build_batch(engine, wl, cfg, batch, prompt_len=16,
                        decode_len=tokens)
    _drive(engine, wl, warm, mode, tokens)
    s0 = engine.sanitizer_stats()
    reqs = _build_batch(engine, wl, cfg, batch, prompt_len=16,
                        decode_len=tokens)
    steady = _drive(engine, wl, reqs, mode, tokens)
    s1 = engine.sanitizer_stats()
    toks = [engine.states[r.rid].generated for r in reqs]
    # runtime sanitizer gate over the timed window: the steady pass hits
    # only warmed jit entries (0 retraces, any mode), and fused dispatch
    # costs at most ONE host sync per committed run — the contract the
    # speedup rests on, asserted on every bench/CI run
    sanitizer = {"steady_retraces": s1.retraces - s0.retraces,
                 "steady_syncs": s1.host_syncs - s0.host_syncs,
                 "steady_runs": s1.runs - s0.runs,
                 "max_syncs_per_run": s1.max_syncs_per_run}
    if sanitizer["steady_retraces"] != 0:
        raise RuntimeError(
            f"{mode}: steady pass retraced {sanitizer['steady_retraces']}x "
            f"after a full warmup — a jit-cache key leaked a dynamic scalar")
    if mode == "fused":
        if sanitizer["steady_runs"] <= 0:
            raise RuntimeError(
                "fused: timed window committed zero runs — the bench "
                "drove no decode steps, nothing was measured")
        if sanitizer["steady_syncs"] > sanitizer["steady_runs"]:
            raise RuntimeError(
                f"fused: {sanitizer['steady_syncs']} host syncs over "
                f"{sanitizer['steady_runs']} committed runs — a hidden "
                f"sync crept into the hot path")
        if s1.max_syncs_per_run > 1:
            raise RuntimeError(
                f"fused: {s1.max_syncs_per_run} host syncs in one "
                f"committed run (limit 1) — sanitizer stats: {s1}")
    # median is the headline number: robust to scheduler noise on shared
    # CPU runners (mean/min recorded alongside)
    return (float(np.median(steady)), float(np.mean(steady)),
            float(np.min(steady)), toks, sanitizer)


def bench_shrink(cfg, wl, *, batch, max_len, repeats=3):
    """Reclamation cost: burst ``4 * batch`` requests into a paged arena
    (grows 4x), drain all but two (which must RELOCATE during the
    compaction), and time the shrink itself. Reported per shrink event —
    reclamation is rare and off the decode path, but its cost must be
    tracked so it stays that way."""
    import jax

    times, before_after = [], None
    for rep in range(repeats):
        engine = JaxEngine(cfg, max_len=max_len, n_slots=batch,
                           max_slots=batch * 8, auto_shrink=False)
        reqs = _build_batch(engine, wl, cfg, 4 * batch, prompt_len=16,
                            decode_len=2, seed=rep)
        for r in reqs:                       # prefill: occupy 4*batch slots
            sb = SubBatch([r])
            run = sb.run_nodes(stop_before={"D0"})
            engine.execute_run("m", sb, run)
            sb.advance_n(len(run), 0.0)
        jax.block_until_ready(engine.arenas)
        grown = engine.n_slots
        b0 = engine.memory_stats().bytes_resident
        # drain the burst, keeping the LAST two prefilled requests live:
        # slots are issued in order, so the survivors hold the two highest
        # slot ids — both sit above the shrink watermark and must relocate
        # (the timed cost includes the row copies, not just the slice)
        survivors = reqs[-2:]
        old_slots = {r.rid: engine._slot[r.rid] for r in survivors}
        for r in reqs[:-2]:
            engine.release_slot(r)
        engine._auto_shrink = True
        t0 = time.perf_counter()
        engine._maybe_shrink()
        jax.block_until_ready(engine.arenas)
        times.append(time.perf_counter() - t0)
        if engine.n_shrinks != 1 or engine.n_slots >= grown:
            raise RuntimeError(
                f"shrink bench: expected exactly one shrink below "
                f"{grown} slots, got n_shrinks={engine.n_shrinks}, "
                f"n_slots={engine.n_slots}")
        if any(engine._slot[r.rid] == old_slots[r.rid]
               for r in survivors):
            raise RuntimeError(
                "shrink bench: compaction did not relocate the "
                "surviving slots — the timed cost excludes row copies")
        before_after = (grown, engine.n_slots, b0,
                        engine.memory_stats().bytes_resident)
    slots_before, slots_after, bytes_before, bytes_after = before_after
    return {"median_ms_per_shrink": float(np.median(times)) * 1e3,
            "min_ms_per_shrink": float(np.min(times)) * 1e3,
            "slots_before": slots_before, "slots_after": slots_after,
            "bytes_before": bytes_before, "bytes_after": bytes_after}


def check_baseline(rec: dict, path: Path, tolerance: float) -> bool:
    """Perf gate: fail when any mode's median ms/token regressed more than
    ``tolerance`` vs the committed baseline artifact (configs must match —
    a smoke run is never judged against a full-run baseline)."""
    base = json.loads(path.read_text())
    keys = ("arch", "batch", "max_len", "tokens", "smoke", "backend")
    mismatched = [k for k in keys if base.get(k) != rec.get(k)]
    if mismatched:
        print(f"baseline {path} config mismatch on {mismatched} — "
              f"skipping regression gate")
        return True
    ok = True
    for mode in MODES:
        old = base[mode]["median_ms_per_token"]
        new = rec[mode]["median_ms_per_token"]
        ratio = new / old
        verdict = "OK" if ratio <= 1.0 + tolerance else "REGRESSED"
        if verdict == "REGRESSED":
            ok = False
        print(f"  perf gate {mode:>7}: {old:8.2f} -> {new:8.2f} ms/token "
              f"({ratio:5.2f}x)  {verdict}")
    return ok


def run(quick: bool = True) -> dict:
    # programmatic suite entry: never writes the tracked artifact (quick
    # configs would clobber the committed 24-token numbers)
    args = argparse.Namespace(arch="llama3.2-1b", batch=8, max_len=256,
                              tokens=12 if quick else 24,
                              smoke=False, out=None, write=False,
                              baseline=None, tolerance=0.2)
    return _run(args)


def _run(args) -> dict:
    import jax
    cfg = get_config(args.arch).reduced()
    if args.smoke:
        cfg = dataclasses.replace(cfg, d_model=64, d_ff=128, vocab_size=256,
                                  num_prefix_embeddings=0)
    wl = from_model_config(cfg,
                          prompt_dist=LengthDist((16,), (1.0,)),
                          decode_dist=LengthDist((4,), (1.0,)))
    rec = {"arch": args.arch, "batch": args.batch, "max_len": args.max_len,
           "tokens": args.tokens, "smoke": bool(args.smoke),
           "backend": jax.default_backend()}
    all_toks = {}
    for mode in MODES:
        med_s, mean_s, min_s, toks, sanitizer = bench_mode(
            mode, cfg, wl, batch=args.batch, max_len=args.max_len,
            tokens=args.tokens)
        all_toks[mode] = toks
        rec[mode] = {"median_ms_per_token": med_s * 1e3,
                     "mean_ms_per_token": mean_s * 1e3,
                     "min_ms_per_token": min_s * 1e3,
                     "sanitizer": sanitizer}
        print(f"{mode:>7}: {med_s * 1e3:8.2f} ms/token median "
              f"({mean_s * 1e3:.2f} mean, {min_s * 1e3:.2f} min) "
              f"over {args.tokens} steady tokens")
    rec["tokens_bitexact"] = (all_toks["legacy"] == all_toks["arena"]
                              == all_toks["fused"])
    if not rec["tokens_bitexact"]:
        raise RuntimeError(
            "generated tokens diverged across dispatch modes — legacy/"
            "arena/fused must be bit-exact on the same seed")
    rec["speedup_arena_vs_legacy"] = (rec["legacy"]["median_ms_per_token"]
                                      / rec["arena"]["median_ms_per_token"])
    rec["speedup_fused_vs_arena"] = (rec["arena"]["median_ms_per_token"]
                                     / rec["fused"]["median_ms_per_token"])
    print(f"tokens bit-exact across modes: {rec['tokens_bitexact']}")
    print(f"speedup: {rec['speedup_arena_vs_legacy']:.1f}x arena vs legacy, "
          f"{rec['speedup_fused_vs_arena']:.1f}x fused vs arena "
          f"(batch {args.batch}, max_len {args.max_len})")
    rec["shrink"] = bench_shrink(cfg, wl, batch=args.batch,
                                 max_len=args.max_len,
                                 repeats=1 if args.smoke else 3)
    sh = rec["shrink"]
    print(f" shrink: {sh['median_ms_per_shrink']:8.2f} ms/reclamation "
          f"({sh['slots_before']} -> {sh['slots_after']} slots, "
          f"{sh['bytes_before'] / 2**20:.0f} -> "
          f"{sh['bytes_after'] / 2**20:.0f} MiB resident)")
    if args.out:
        out = Path(args.out)
    elif getattr(args, "write", True) and not args.smoke:
        # full CLI runs refresh the tracked artifact; smoke/programmatic
        # runs must not clobber it
        out = Path(__file__).resolve().parent.parent / "BENCH_engine_decode.json"
    else:
        out = None
    # gate BEFORE writing: the tracked artifact may itself be the baseline,
    # and a regressed run must not overwrite the numbers it is judged by
    if getattr(args, "baseline", None):
        if not check_baseline(rec, Path(args.baseline), args.tolerance):
            raise SystemExit(
                f"decode bench regressed >"
                f"{args.tolerance * 100:.0f}% vs {args.baseline}")
    if out is not None:
        out.write_text(json.dumps(rec, indent=2) + "\n")
        print(f"wrote {out}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=24,
                    help="steady-state tokens timed per mode (a full "
                         "warmup pass of the same length runs first)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + short run (CI rot guard)")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default: repo root)")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH json to gate against: exit "
                         "non-zero when any mode's median ms/token "
                         "regressed more than --tolerance")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional regression vs --baseline "
                         "(default 0.2 = 20%%)")
    args = ap.parse_args()
    if args.smoke:
        args.batch = min(args.batch, 4)
        args.max_len = min(args.max_len, 64)
        args.tokens = min(args.tokens, 4)
    _run(args)


if __name__ == "__main__":
    main()
