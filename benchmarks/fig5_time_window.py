"""Fig. 4/5: effect of the batching time-window on graph batching.

At low traffic a long window only adds latency (no extra batch members
arrive); under heavy traffic it buys throughput. This is the static
"one-size-fits-all" failure LazyBatching removes.
"""
import numpy as np

from repro.core.policies import GraphBatching
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import InferenceServer, SimExecutor
from repro.serving.traffic import poisson_trace
from repro.serving.workload import get_workload
from .common import fmt_table


def run(quick: bool = True) -> dict:
    perf = NPUPerfModel()
    wl = get_workload("resnet")
    rates = [16, 250, 2000]            # paper's low/medium/high
    windows = [0.005, 0.025, 0.050, 0.099]
    dur = 0.5 if quick else 2.0
    rows, rec = [], {}
    for rate in rates:
        for w in windows:
            lats, bsz = [], []
            for seed in (0, 1):
                trace = poisson_trace(wl, rate, dur, seed=seed)
                pol = GraphBatching(window=w)
                srv = InferenceServer(pol, SimExecutor(perf))
                stats = srv.run(trace)
                lats.append(stats.avg_latency)
                bsz.append(srv.log.avg_batch_size)
            rec[(rate, w)] = {"avg_ms": float(np.mean(lats)) * 1e3,
                              "avg_batch": float(np.mean(bsz))}
            rows.append([rate, f"{w * 1e3:g}", f"{np.mean(bsz):.1f}",
                         f"{np.mean(lats) * 1e3:.2f}"])
    print("\n# Fig. 5 — batching time-window (BTW) effect, ResNet")
    print(fmt_table(rows, ["rate r/s", "BTW ms", "avg batch", "avg lat ms"]))
    # claims: at 16 r/s a larger window only hurts latency and batch stays ~1;
    # at 2000 r/s the larger window forms real batches
    low_flat = rec[(16, 0.099)]["avg_batch"] < 4.0
    low_hurts = rec[(16, 0.099)]["avg_ms"] > rec[(16, 0.005)]["avg_ms"] * 2
    high_batches = rec[(2000, 0.099)]["avg_batch"] > 4.0
    print(f"low-load window useless: {low_flat and low_hurts}; "
          f"high-load window batches: {high_batches}")
    return {"low_flat": low_flat, "low_hurts": low_hurts,
            "high_batches": high_batches,
            "table": {f"{r}@{w}": v for (r, w), v in rec.items()}}
