"""Fig. 15: SLA violation rate vs SLA deadline (high load, 1K req/s).

Claims: graph batching violates heavily even at loose SLAs; LazyBatching
reaches ~zero violations once the deadline exceeds ~20/40/60 ms for
ResNet/GNMT/Transformer; LazyBatching stays close to Oracle; violation
rate decreases monotonically with the deadline.
"""
import numpy as np

from repro.core.policies import GraphBatching, LazyBatching, Oracle
from repro.core.slack import OracleSlackPredictor, SlackPredictor
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import run_policy
from repro.serving.traffic import poisson_trace
from repro.serving.workload import get_workload
from .common import fmt_table

DEADLINES = (0.020, 0.040, 0.060, 0.080, 0.100)
ZERO_BY = {"resnet": 0.020, "gnmt": 0.040, "transformer": 0.060}


def run(quick: bool = True) -> dict:
    perf = NPUPerfModel()
    dur = 0.25 if quick else 2.0
    rec, rows = {}, []
    for wname in ("resnet", "gnmt", "transformer"):
        wl = get_workload(wname)
        trace = poisson_trace(wl, 1000.0, dur, seed=0)
        rec[wname] = {}
        for sla in DEADLINES:
            lazy = run_policy(
                LazyBatching(SlackPredictor.build([wl], perf, sla)),
                trace, perf).sla_violation_rate(sla)
            orc = run_policy(
                Oracle(OracleSlackPredictor(sla, perf)),
                trace, perf).sla_violation_rate(sla)
            # graph batching with a window compatible with the deadline
            gbs = [run_policy(GraphBatching(window=w), trace,
                              perf).sla_violation_rate(sla)
                   for w in (0.005, 0.025, 0.075) if w < sla]
            gb = float(np.min(gbs))
            rec[wname][sla] = {"lazyb": lazy, "oracle": orc, "best_graphb": gb}
            rows.append([wname, f"{sla * 1e3:g}", f"{gb * 100:.1f}%",
                         f"{lazy * 100:.1f}%", f"{orc * 100:.1f}%"])
    print("\n# Fig. 15 — SLA violation rate @1K req/s")
    print(fmt_table(rows, ["workload", "deadline ms", "best graphb",
                           "lazyb", "oracle"]))
    checks = {}
    for wname, per in rec.items():
        v = [per[s]["lazyb"] for s in DEADLINES]
        checks[wname] = {
            "monotone_nonincreasing": all(v[i] >= v[i + 1] - 1e-9
                                          for i in range(len(v) - 1)),
            "zero_at_loose": per[0.100]["lazyb"] == 0.0,
            "near_oracle": abs(per[0.100]["lazyb"]
                               - per[0.100]["oracle"]) < 0.05,
        }
    print("checks:", checks)
    return {"rates": {w: {f"{s * 1e3:g}ms": v for s, v in per.items()}
                      for w, per in rec.items()}, "checks": checks}
