"""Run the full benchmark suite (one module per paper table/figure).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig12,fig15]
"""
import argparse
import json
import time

from . import (bursty_traffic, colocation, dec_timesteps, fig3_batch_curve,
               fig5_time_window, fig12_latency, fig13_throughput, fig14_cdf,
               fig15_sla, fig16_robustness, fig17_chaos,
               max_batch_sensitivity, roofline_report, table2_latency)

SUITES = {
    "table2": table2_latency,
    "fig3": fig3_batch_curve,
    "fig5": fig5_time_window,
    "fig12": fig12_latency,
    "fig13": fig13_throughput,
    "fig14": fig14_cdf,
    "fig15": fig15_sla,
    "fig16": fig16_robustness,
    "fig17": fig17_chaos,
    "dec_timesteps": dec_timesteps,
    "max_batch": max_batch_sensitivity,
    "colocation": colocation,
    "bursty": bursty_traffic,
    "roofline": roofline_report,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale durations/seeds (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SUITES))
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    names = args.only.split(",") if args.only else list(SUITES)
    results, t0 = {}, time.perf_counter()
    for name in names:
        t = time.perf_counter()
        results[name] = SUITES[name].run(quick=not args.full)
        print(f"[{name} done in {time.perf_counter() - t:.1f}s]")
    print(f"\nall {len(names)} benchmarks done "
          f"in {time.perf_counter() - t0:.1f}s")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=1, default=str)


if __name__ == "__main__":
    main()
