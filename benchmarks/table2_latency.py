"""Table II validation: single-batch end-to-end inference latency.

Paper: ResNet 1.1 ms, GNMT 7.2 ms, Transformer 2.4 ms on the Table-I NPU.
Our analytical NPU model must land in the same regime and preserve the
ordering (the scheduler only consumes relative node latencies). seq2seq
latencies are evaluated at the WMT mean sentence length (~13 words) —
the paper does not state its length assumption.
"""
from repro.serving.npu_model import NPUPerfModel
from repro.serving.workload import get_workload
from .common import fmt_table

PAPER_MS = {"resnet": 1.1, "gnmt": 7.2, "transformer": 2.4}


def run(quick: bool = True) -> dict:
    perf = NPUPerfModel()
    rows, rec = [], {}
    for name, paper in PAPER_MS.items():
        wl = get_workload(name)
        if wl.prompt_dist:
            mean_len = int(round(wl.prompt_dist.mean))
            ours = perf.single_input_exec_time(wl, mean_len, mean_len)
        else:
            ours = perf.single_input_exec_time(wl, 0, 0)
        rec[name] = ours * 1e3
        rows.append([name, f"{paper:.1f}", f"{ours * 1e3:.2f}",
                     f"{ours * 1e3 / paper:.2f}x"])
    print("\n# Table II — single-batch latency (paper NPU vs our model)")
    print(fmt_table(rows, ["workload", "paper ms", "ours ms", "ratio"]))
    order_ok = rec["resnet"] < rec["transformer"] < rec["gnmt"]
    within = all(0.3 < rec[k] / PAPER_MS[k] < 3.0 for k in PAPER_MS)
    print(f"ordering preserved: {order_ok}; all within 3x: {within}")
    return {"table": rec, "order_ok": order_ok, "within_3x": within}
