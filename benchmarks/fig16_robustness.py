"""Fig. 16: robustness across additional benchmarks (VGG, MobileNet, LAS,
BERT) — and, beyond the paper, across all 10 assigned architectures.

Paper claim: averaged over the four extra workloads, 1.5x latency, 1.3x
throughput, 2.9x SLA-satisfaction improvement vs the best graph batching.
"""
import numpy as np

from .common import best_graphb, fmt_table, sweep

PAPER_EXTRA = ("vggnet", "mobilenet", "las", "bert")
ASSIGNED = ("llama3.2-1b", "mamba2-2.7b", "granite-moe-3b-a800m",
            "recurrentgemma-9b", "minicpm3-4b", "musicgen-large",
            "qwen2.5-32b", "mistral-nemo-12b", "internvl2-26b",
            "grok-1-314b")


def _one(wname, quick):
    from repro.serving.npu_model import NPUPerfModel
    from repro.serving.workload import get_workload

    # assigned LLM/SSM archs run 10-1000x longer per request than the
    # paper's vision/translation workloads on the Table-I NPU; scale the
    # SLA and offered load to each workload's single-input time so the
    # experiment probes the same operating regime for every model.
    wl = get_workload(wname)
    perf = NPUPerfModel()
    if wl.prompt_dist:
        m = int(round(wl.prompt_dist.mean))
        d = int(round(wl.decode_dist.mean)) if wl.decode_dist else 0
        single = perf.single_input_exec_time(wl, m, d)
    else:
        single = perf.single_input_exec_time(wl, 0, 0)
    sla = max(0.1, 12 * single)
    low, high = 0.25 / single, 3.0 / single
    dur = (0.15 if quick else 1.0) * max(1.0, single / 1.1e-3) ** 0.5
    dur = min(dur, 40 * single * 3)
    rates = (low, high)
    windows = tuple(min(w * sla / 0.1, sla * 0.9) for w in (0.005, 0.025, 0.075))
    res = sweep(wname, list(rates), duration=dur,
                seeds=(0,) if quick else (0, 1), sla=sla,
                policies=(["serial"] + [("graphb", w) for w in windows]
                          + ["lazyb"]))
    lat_gain, thr_gain, viol = [], [], []
    for rate in rates:
        pp = res[rate]
        _, bg_l = best_graphb(pp)
        _, bg_t = best_graphb(pp, "throughput_rps", minimize=False)
        lat_gain.append(bg_l["avg_latency_ms"] / pp["lazyb"]["avg_latency_ms"])
        thr_gain.append(pp["lazyb"]["throughput_rps"]
                        / max(bg_t["throughput_rps"], 1e-9))
        _, bg_v = best_graphb(pp, "sla_violation_rate")
        viol.append((bg_v["sla_violation_rate"],
                     pp["lazyb"]["sla_violation_rate"]))
    return {"lat_gain": float(np.mean(lat_gain)),
            "thr_gain": float(np.mean(thr_gain)),
            "viol_graphb": float(np.mean([v[0] for v in viol])),
            "viol_lazyb": float(np.mean([v[1] for v in viol]))}


def run(quick: bool = True) -> dict:
    rec, rows = {}, []
    names = PAPER_EXTRA + (ASSIGNED[:3] if quick else ASSIGNED)
    for wname in names:
        r = _one(wname, quick)
        rec[wname] = r
        rows.append([wname, f"{r['lat_gain']:.2f}x", f"{r['thr_gain']:.2f}x",
                     f"{r['viol_graphb'] * 100:.1f}%",
                     f"{r['viol_lazyb'] * 100:.1f}%"])
    print("\n# Fig. 16 — robustness (lazyb vs best graphb; latency gain "
          "averaged over 16/1000 r/s)")
    print(fmt_table(rows, ["workload", "lat gain", "thr gain",
                           "graphb viol", "lazyb viol"]))
    lat = float(np.mean([r["lat_gain"] for r in rec.values()]))
    thr = float(np.mean([r["thr_gain"] for r in rec.values()]))
    print(f"averages: {lat:.2f}x latency, {thr:.2f}x throughput "
          f"(paper fig16: 1.5x, 1.3x on its four extras)")
    return {"per_workload": rec, "avg_lat_gain": lat, "avg_thr_gain": thr}
