"""§VI-C: co-located multi-model inference.

Four models deployed on one server; LazyBatching authorizes a new request
only if lazily batching it keeps the SLAs of ALL co-located ongoing
requests. Requests of different models can interleave at node level but
only merge with same-model sub-batches (no common weights across models —
the BatchTable's node-id equality already enforces this since node ids are
namespaced per workload).

Paper claim: 2.4x latency / 1.8x throughput vs graph batching under
4-model co-location.
"""
import numpy as np

from repro.core.policies import GraphBatching, LazyBatching, Serial
from repro.core.slack import SlackPredictor
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import run_policy
from repro.serving.traffic import colocated_trace
from repro.serving.workload import get_workload
from .common import DEFAULT_SLA, fmt_table

MODELS = ("resnet", "gnmt", "transformer", "mobilenet")


def run(quick: bool = True) -> dict:
    perf = NPUPerfModel()
    wls = [get_workload(m) for m in MODELS]
    # cross-model merges are impossible only while every model is a
    # distinct Workload object (SubBatch.mergeable_with compares the
    # workload by identity — node ids like "head"/"emb" collide)
    if len({id(wl) for wl in wls}) != len(wls):
        raise RuntimeError(
            "co-location bench needs one distinct Workload per model; "
            f"got aliased workload objects for {MODELS}")
    dur = 0.5 if quick else 2.0
    rec = {}
    pred = SlackPredictor.build(wls, perf, DEFAULT_SLA)
    policies = [("serial", lambda: Serial()),
                ("graphb(25ms)", lambda: GraphBatching(0.025)),
                ("graphb(75ms)", lambda: GraphBatching(0.075)),
                ("lazyb", lambda: LazyBatching(pred))]
    for per_model_rate in (150.0, 350.0):
        rates = [per_model_rate] * len(wls)
        rows, sums = [], {}
        for name, mk in policies:
            per_seed = []
            for seed in ((0,) if quick else (0, 1, 2)):
                trace = colocated_trace(wls, rates, dur, seed=seed)
                per_seed.append(run_policy(mk(), trace, perf)
                                .summary(sla=DEFAULT_SLA))
            sums[name] = {k: float(np.mean([s[k] for s in per_seed]))
                          for k in per_seed[0] if k != "policy"}
            s = sums[name]
            rows.append([name, f"{s['avg_latency_ms']:.2f}",
                         f"{s['throughput_rps']:.0f}",
                         f"{s['sla_violation_rate'] * 100:.1f}%"])
        agg = per_model_rate * len(wls)
        print(f"\n# Co-location — 4 models on one server "
              f"({agg:g} req/s aggregate)")
        print(fmt_table(rows, ["policy", "avg ms", "thr r/s", "SLA viol"]))
        gb = min((v for k, v in sums.items() if k.startswith("graphb")),
                 key=lambda v: v["avg_latency_ms"])
        lat_gain = gb["avg_latency_ms"] / sums["lazyb"]["avg_latency_ms"]
        thr_gain = sums["lazyb"]["throughput_rps"] / gb["throughput_rps"]
        print(f"lazyb vs best graphb: {lat_gain:.2f}x latency, "
              f"{thr_gain:.2f}x throughput (paper: 2.4x / 1.8x); "
              f"vs serial: "
              f"{sums['serial']['avg_latency_ms'] / sums['lazyb']['avg_latency_ms']:.1f}x")
        rec[f"{agg:g}rps"] = {"summaries": sums, "lat_gain": lat_gain,
                              "thr_gain": thr_gain}
    return rec
