"""Beyond-paper robustness: bursty (MMPP) arrivals.

The paper evaluates Poisson traffic only; production traffic bursts. A
two-state MMPP alternates 0.3x/2x the nominal rate — the regime where a
statically-tuned batching window is maximally wrong in both directions
(too long in the valley, too short in the burst). LazyBatching's
adaptivity claim predicts its advantage *grows* vs Poisson.
"""
import numpy as np

from repro.core.policies import GraphBatching, LazyBatching
from repro.core.slack import SlackPredictor
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import run_policy
from repro.serving.traffic import bursty_trace, poisson_trace
from repro.serving.workload import get_workload
from .common import DEFAULT_SLA, fmt_table


def run(quick: bool = True) -> dict:
    perf = NPUPerfModel()
    dur = 0.6 if quick else 2.0
    rate = 500.0
    rec, rows = {}, []
    for wname in ("resnet", "transformer"):
        wl = get_workload(wname)
        pred = SlackPredictor.build([wl], perf, DEFAULT_SLA)
        for shape, mk_trace in (
                ("poisson", lambda s: poisson_trace(wl, rate, dur, seed=s)),
                ("bursty", lambda s: bursty_trace(
                    wl, rate * 0.3, rate * 2.0, dur / 6, dur, seed=s))):
            gains = []
            for seed in ((0,) if quick else (0, 1, 2)):
                trace = mk_trace(seed)
                lz = run_policy(LazyBatching(pred), trace, perf).avg_latency
                gb = min(run_policy(GraphBatching(w), trace, perf).avg_latency
                         for w in (0.005, 0.025, 0.075))
                gains.append(gb / lz)
            g = float(np.mean(gains))
            rec[(wname, shape)] = g
            rows.append([wname, shape, f"{g:.2f}x"])
    print("\n# Bursty traffic (beyond paper) — lazyb vs best graphb latency")
    print(fmt_table(rows, ["workload", "arrivals", "lazyb gain"]))
    grows = all(rec[(w, "bursty")] >= 1.5 for w in ("resnet", "transformer"))
    print(f"adaptivity holds under bursts (lazyb stays >= 1.5x the best "
          f"statically-tuned window): {grows}")
    return {"gains": {f"{w}/{s}": v for (w, s), v in rec.items()},
            "holds": grows}
