"""Open-loop async load generator for the serving gateway.

Drives a live gateway (``python -m repro.launch.gateway``) with a
seeded Poisson or bursty (two-state MMPP) arrival process — open loop:
arrival times are drawn up front and honored regardless of response
latency, so an overloaded server cannot slow the offered load down
(the classic closed-loop coordination-omission trap). Each arrival is
one ``POST /v1/generate`` exchange over a fresh connection; SSE events
are consumed as they stream and the terminal ``done``/``error`` event
supplies the session-clock latency/TTFT the SLA numbers are judged on
(wall figures are recorded alongside).

Reports p50/p95/p99 latency, TTFT, per-tier attainment, and error/shed
rates to ``BENCH_gateway.json``.

Two modes:

  * **live** — aim at an already-running gateway (``--host``/``--port``).
  * **spawn** — launch one gateway subprocess per policy from a command
    template (``--spawn "... --policy {policy} --port {port} ..."``,
    ``--policies lazyb,graphb``), wait on ``/readyz``, replay the SAME
    seeded arrival sequence against each, SIGTERM it, and gate on a
    clean drain (exit 0). This produces the lazyb-vs-graphb comparison
    artifact CI uploads.

Example (sim backend, 50x compression, overload mixture)::

    python benchmarks/loadgen.py --rate 400 --duration 4 \
        --tiers gold:0.05:0.3,bulk:0.5:0.7 \
        --spawn "python -m repro.launch.gateway --policy {policy} \
                 --port {port} --time-scale 50 --mem-slots 48 \
                 --max-queue 256 --sla-tiers gold:0.05,bulk:0.5 \
                 --assert-no-leak --quiet" \
        --policies lazyb,graphb --json-out BENCH_gateway.json
"""
from __future__ import annotations

import argparse
import asyncio
import json
import shlex
import signal
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# arrival processes (seeded; identical across compared policies)
# ---------------------------------------------------------------------------

def poisson_arrivals(rate: float, duration: float,
                     rng: np.random.Generator) -> List[float]:
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= duration:
            return out
        out.append(t)


def bursty_arrivals(rate: float, duration: float,
                    rng: np.random.Generator) -> List[float]:
    """Two-state MMPP: alternate lo (0.3x) / hi (2x) phases so the mean
    offered load stays near ``rate`` while bursts stress the queue."""
    out, t, hi = [], 0.0, False
    period = duration / 6.0
    while t < duration:
        phase_rate = rate * (2.0 if hi else 0.3)
        end = min(t + period, duration)
        tt = t
        while True:
            tt += rng.exponential(1.0 / phase_rate)
            if tt >= end:
                break
            out.append(tt)
        t, hi = end, not hi
    return out


def parse_tiers(spec: Optional[str]) -> List[Tuple[str, float, float]]:
    """``name:deadline_s:weight[,...]`` -> [(name, deadline, weight)]."""
    if not spec:
        return [("default", float("nan"), 1.0)]
    tiers = []
    for part in spec.split(","):
        name, deadline, weight = part.strip().split(":")
        tiers.append((name, float(deadline), float(weight)))
    total = sum(w for _, _, w in tiers)
    return [(n, d, w / total) for n, d, w in tiers]


def parse_models(spec: Optional[str]) -> List[Tuple[str, float]]:
    if not spec:
        return []
    pairs = []
    for part in spec.split(","):
        name, _, share = part.strip().rpartition(":")
        pairs.append((name, float(share)))
    total = sum(s for _, s in pairs)
    return [(n, s / total) for n, s in pairs]


# ---------------------------------------------------------------------------
# one HTTP exchange over raw asyncio streams
# ---------------------------------------------------------------------------

async def _read_headers(reader) -> Tuple[int, Dict[str, str]]:
    status_line = await reader.readline()
    parts = status_line.decode("latin-1").split(" ", 2)
    status = int(parts[1])
    headers: Dict[str, str] = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        name, _, value = line.decode("latin-1").partition(":")
        headers[name.strip().lower()] = value.strip()
    return status, headers


async def do_request(host: str, port: int, path: str, body: dict,
                     t0: float) -> dict:
    """One exchange; returns the per-request record."""
    loop = asyncio.get_running_loop()
    result = {"status": 0, "fate": None, "tokens": 0,
              "latency_s": None, "ttft_s": None,
              "wall_ms": None, "ttfb_wall_ms": None}
    reader, writer = await asyncio.open_connection(host, port)
    try:
        payload = json.dumps(body).encode("utf-8")
        head = (f"POST {path} HTTP/1.1\r\nhost: {host}\r\n"
                f"content-type: application/json\r\n"
                f"content-length: {len(payload)}\r\n"
                f"connection: close\r\n\r\n")
        writer.write(head.encode("latin-1") + payload)
        await writer.drain()
        status, headers = await _read_headers(reader)
        result["status"] = status
        result["ttfb_wall_ms"] = (loop.time() - t0) * 1e3
        if headers.get("retry-after"):
            result["retry_after"] = float(headers["retry-after"])
        if headers.get("content-type", "").startswith("text/event-stream"):
            async for event, data in _sse_events(reader):
                if event == "token":
                    result["tokens"] += 1
                elif event in ("done", "error"):
                    result["fate"] = data.get("fate", event)
                    result["latency_s"] = data.get("latency_s")
                    result["ttft_s"] = data.get("ttft_s")
                    if event == "error":
                        result["status"] = data.get("status", 500)
        else:
            length = int(headers.get("content-length", "0"))
            raw = await reader.readexactly(length) if length else b""
            if raw:
                data = json.loads(raw.decode("utf-8"))
                result["fate"] = data.get("error", data.get("fate"))
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except ConnectionError:
            pass
    result["wall_ms"] = (loop.time() - t0) * 1e3
    return result


async def _sse_events(reader):
    event, data_lines = None, []
    while True:
        line = await reader.readline()
        if not line:
            return
        text = line.decode("utf-8").rstrip("\r\n")
        if not text:
            if event is not None or data_lines:
                payload = {}
                if data_lines:
                    try:
                        payload = json.loads("\n".join(data_lines))
                    except ValueError:
                        payload = {"raw": "\n".join(data_lines)}
                yield event or "message", payload
            event, data_lines = None, []
            continue
        if text.startswith("event:"):
            event = text[len("event:"):].strip()
        elif text.startswith("data:"):
            data_lines.append(text[len("data:"):].strip())


async def fetch(host: str, port: int, path: str) -> Tuple[int, bytes]:
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write((f"GET {path} HTTP/1.1\r\nhost: {host}\r\n"
                      f"connection: close\r\n\r\n").encode("latin-1"))
        await writer.drain()
        status, headers = await _read_headers(reader)
        body = await reader.read()
        return status, body
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except ConnectionError:
            pass


# ---------------------------------------------------------------------------
# one load run
# ---------------------------------------------------------------------------

async def run_load(args, host: str, port: int) -> dict:
    rng = np.random.default_rng(args.seed)
    arrivals = (bursty_arrivals if args.bursty else poisson_arrivals)(
        args.rate, args.duration, rng)
    tiers = parse_tiers(args.tiers)
    models = parse_models(args.models)
    tier_idx = rng.choice(len(tiers), size=len(arrivals),
                          p=[w for _, _, w in tiers])
    model_idx = (rng.choice(len(models), size=len(arrivals),
                            p=[s for _, s in models])
                 if models else None)
    loop = asyncio.get_running_loop()
    t_start = loop.time()
    records: List[Optional[dict]] = [None] * len(arrivals)
    metrics_scrape: Dict[str, List[str]] = {}
    loop_scrape: Dict[str, float] = {}       # parsed gateway_loop_* values

    async def one(i: int, at: float) -> None:
        await asyncio.sleep(max(0.0, (t_start + at) - loop.time()))
        name, _, _ = tiers[tier_idx[i]]
        body = {"sla_class": name} if name != "default" else {}
        if model_idx is not None:
            body["model"] = models[model_idx[i]][0]
        t0 = loop.time()
        try:
            records[i] = await asyncio.wait_for(
                do_request(host, port, "/v1/generate", body, t0),
                timeout=args.client_timeout)
        except (ConnectionError, asyncio.TimeoutError, OSError) as exc:
            records[i] = {"status": -1, "fate": type(exc).__name__,
                          "tokens": 0, "latency_s": None, "ttft_s": None,
                          "wall_ms": (loop.time() - t0) * 1e3,
                          "ttfb_wall_ms": None}
        records[i]["tier"] = name

    async def scrape() -> None:
        # mid-run /metrics snapshot: proves live per-model attainment,
        # queue depth and arena residency are exposed under load
        await asyncio.sleep(args.duration * 0.7)
        try:
            _, text = await fetch(host, port, "/metrics")
        except (ConnectionError, OSError):
            return
        wanted = ("gateway_attainment", "gateway_queue_depth",
                  "gateway_arena_", "gateway_inflight",
                  "gateway_loop_")
        for line in text.decode("utf-8").splitlines():
            if line.startswith(wanted):
                key = line.split("{")[0].split(" ")[0]
                metrics_scrape.setdefault(key, []).append(line)
                if key.startswith("gateway_loop_"):
                    try:
                        loop_scrape[key] = float(line.rsplit(" ", 1)[1])
                    except (ValueError, IndexError):
                        pass

    tasks = [asyncio.create_task(one(i, at))
             for i, at in enumerate(arrivals)]
    if args.scrape_metrics:
        tasks.append(asyncio.create_task(scrape()))
    await asyncio.gather(*tasks)
    report = summarize([r for r in records if r is not None], tiers, args)
    if metrics_scrape:
        report["metrics_scrape"] = metrics_scrape
    if loop_scrape:
        # event-loop health from the gateway's stall watchdog: the CI
        # gate reads max-stall/stalls, the artifact keeps lag p99 too
        report["loop"] = {
            "max_stall_s": loop_scrape.get(
                "gateway_loop_max_stall_seconds"),
            "lag_p99_s": loop_scrape.get(
                "gateway_loop_lag_p99_seconds"),
            "stalls": loop_scrape.get("gateway_loop_stalls_total"),
            "ticks": loop_scrape.get("gateway_loop_ticks_total"),
        }
    return report


def _pcts(xs: List[float]) -> dict:
    if not xs:
        return {"mean": None, "p50": None, "p95": None, "p99": None}
    arr = np.asarray(xs)
    return {"mean": round(float(arr.mean()), 4),
            "p50": round(float(np.percentile(arr, 50)), 4),
            "p95": round(float(np.percentile(arr, 95)), 4),
            "p99": round(float(np.percentile(arr, 99)), 4)}


def summarize(records: List[dict],
              tiers: List[Tuple[str, float, float]], args) -> dict:
    by_status: Dict[str, int] = {}
    by_fate: Dict[str, int] = {}
    for r in records:
        by_status[str(r["status"])] = by_status.get(str(r["status"]), 0) + 1
        if r["fate"]:
            by_fate[r["fate"]] = by_fate.get(r["fate"], 0) + 1
    done = [r for r in records if r["fate"] == "done"]
    lat = [r["latency_s"] * 1e3 for r in done
           if r["latency_s"] is not None]
    ttft = [r["ttft_s"] * 1e3 for r in done if r["ttft_s"] is not None]
    # per-tier attainment over every SUBMITTED request of the tier
    # (errors/sheds are misses), matching ServeStats' accounting
    attainment = {}
    for name, deadline, _ in tiers:
        if np.isnan(deadline):
            continue
        mine = [r for r in records if r.get("tier") == name]
        if mine:
            ok = sum(1 for r in mine
                     if r["fate"] == "done" and r["latency_s"] is not None
                     and r["latency_s"] <= deadline)
            attainment[name] = round(ok / len(mine), 4)
    return {
        "submitted": len(records),
        "completed": len(done),
        "statuses": dict(sorted(by_status.items())),
        "fates": dict(sorted(by_fate.items())),
        "backpressure_429": by_status.get("429", 0),
        "shed_503": by_status.get("503", 0),
        "latency_ms": _pcts(lat),
        "ttft_ms": _pcts(ttft),
        "wall_ms": _pcts([r["wall_ms"] for r in records
                          if r["wall_ms"] is not None]),
        "tokens_streamed": sum(r["tokens"] for r in records),
        "attainment": attainment,
    }


# ---------------------------------------------------------------------------
# spawn mode
# ---------------------------------------------------------------------------

async def wait_ready(host: str, port: int, timeout: float = 30.0) -> bool:
    loop = asyncio.get_running_loop()
    deadline = loop.time() + timeout
    while loop.time() < deadline:
        try:
            status, _ = await fetch(host, port, "/readyz")
            if status == 200:
                return True
        except (ConnectionError, OSError):
            pass
        await asyncio.sleep(0.05)
    return False


async def run_spawned(args, policy: str, port: int) -> dict:
    cmd = shlex.split(args.spawn.format(policy=policy, port=port))
    proc = subprocess.Popen(cmd)
    try:
        if not await wait_ready(args.host, port):
            proc.send_signal(signal.SIGTERM)
            proc.wait(timeout=20)
            return {"error": f"gateway for {policy} never became ready"}
        report = await run_load(args, args.host, port)
    except BaseException:
        proc.kill()
        proc.wait(timeout=20)
        raise
    proc.send_signal(signal.SIGTERM)
    try:
        code = proc.wait(timeout=60)
    except subprocess.TimeoutExpired:
        proc.kill()
        code = proc.wait(timeout=20)
    report["gateway_exit"] = code
    report["clean_drain"] = code == 0
    return report


# ---------------------------------------------------------------------------

async def amain(args) -> int:
    doc = {
        "invocation": {"argv": list(sys.argv), "seed": args.seed},
        "config": {"rate": args.rate, "duration": args.duration,
                   "bursty": args.bursty, "tiers": args.tiers,
                   "models": args.models,
                   "client_timeout": args.client_timeout},
        "runs": {},
    }
    failed = False
    if args.spawn:
        policies = [p.strip() for p in args.policies.split(",")]
        for i, policy in enumerate(policies):
            port = args.port + i
            print(f"[loadgen] spawning {policy} gateway on :{port}",
                  file=sys.stderr)
            report = await run_spawned(args, policy, port)
            doc["runs"][policy] = report
            if report.get("error") or not report.get("clean_drain"):
                failed = True
        tight = min(parse_tiers(args.tiers), key=lambda t: t[1])
        if not np.isnan(tight[1]) and len(doc["runs"]) > 1:
            doc["comparison"] = {
                "tight_tier": tight[0],
                "attainment": {p: r.get("attainment", {}).get(tight[0])
                               for p, r in doc["runs"].items()}}
    else:
        doc["runs"]["live"] = await run_load(args, args.host, args.port)
    for name, report in doc["runs"].items():
        if "error" in report:
            print(f"[loadgen] {name}: {report['error']}", file=sys.stderr)
            continue
        print(f"[loadgen] {name}: submitted {report['submitted']}  "
              f"completed {report['completed']}  "
              f"429s {report['backpressure_429']}  "
              f"p99 {report['latency_ms']['p99']}ms  "
              f"attainment {report['attainment']}", file=sys.stderr)
        loop_h = report.get("loop")
        if loop_h and loop_h.get("max_stall_s") is not None:
            print(f"[loadgen] {name}: loop max stall "
                  f"{loop_h['max_stall_s'] * 1e3:.1f}ms  "
                  f"lag p99 {(loop_h['lag_p99_s'] or 0) * 1e3:.1f}ms  "
                  f"stalls {int(loop_h['stalls'] or 0)}",
                  file=sys.stderr)
        if args.assert_completions and (report["completed"]
                                        < args.assert_completions):
            print(f"[loadgen] GATE: {name} completed "
                  f"{report['completed']} < {args.assert_completions}",
                  file=sys.stderr)
            failed = True
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"[loadgen] wrote {args.json_out}", file=sys.stderr)
    return 1 if failed else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="gateway port (spawn mode: first port; each "
                         "additional policy gets port+1, +2, ...)")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="offered load in requests per WALL second")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="wall seconds of offered load")
    ap.add_argument("--bursty", action="store_true",
                    help="two-state MMPP bursts instead of Poisson")
    ap.add_argument("--tiers", default=None,
                    help='"name:deadline_s:weight[,...]" — tier mix and '
                         "the deadlines attainment is judged against "
                         "(session clock)")
    ap.add_argument("--models", default=None,
                    help='"name:share[,...]" model mix (omit for the '
                         "gateway's single registered model)")
    ap.add_argument("--client-timeout", type=float, default=30.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spawn", default=None,
                    help="gateway command template with {policy} and "
                         "{port} placeholders; loadgen manages the "
                         "process per --policies entry")
    ap.add_argument("--policies", default="lazyb",
                    help="comma list of policies for spawn mode")
    ap.add_argument("--scrape-metrics", action="store_true",
                    help="snapshot /metrics mid-run into the artifact")
    ap.add_argument("--assert-completions", type=int, default=None,
                    help="gate: exit 1 when a run completes fewer "
                         "requests than this")
    ap.add_argument("--json-out", default="BENCH_gateway.json")
    args = ap.parse_args(argv)
    return asyncio.run(amain(args))


if __name__ == "__main__":
    sys.exit(main())
