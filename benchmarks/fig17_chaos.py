"""Fig. 17 (beyond the paper): fault-tolerant serving under chaos.

Seeded two-tier overload — a protected *gold* tier with a tight SLA and
a best-effort *bulk* tier at 2-3x device capacity — with injected
transient backend faults and latency-spike stragglers. Two lazyb
variants serve the identical trace through the identical seeded
`FaultInjectingBackend`:

  * ``baseline`` — retry/backoff only (the pre-robustness stack: every
    admitted request is served to completion no matter how late),
  * ``robust``   — retry/backoff **plus** mid-flight deadline
    cancellation, a bounded ingress queue, and brownout shedding of the
    bulk tier (``shed_priority`` 0 < gold's 1).

The claim this records: on BOTH seeds the robust stack holds gold-tier
SLA attainment strictly above the baseline, and neither variant leaks a
KV slot (``memory_stats()`` residency returns to zero after drain).
"""
import numpy as np

from repro.core.policies import LazyBatching
from repro.core.request import SLAClass
from repro.core.slack import SlackPredictor
from repro.serving import (BrownoutConfig, FaultInjectingBackend, FaultSpec,
                           RetryPolicy, ServingSession)
from repro.serving.npu_model import NPUPerfModel
from repro.serving.server import SimExecutor
from repro.serving.traffic import poisson_trace
from repro.serving.workload import get_workload

GOLD_SLA = 0.035                 # tight tier; alone it fits in capacity
BULK_SLA = 0.5                   # best-effort tier; provides the overload
GOLD_SHARE = 0.1                 # fraction of the offered load
SPEC = FaultSpec(p_transient=0.01, p_straggler=0.03, straggler_factor=4.0,
                 fault_latency=0.002)


def _serve(seed: int, rate: float, duration: float, robust: bool):
    wl = get_workload("transformer")
    perf = NPUPerfModel()
    backend = FaultInjectingBackend(SimExecutor(perf), SPEC, seed=seed)
    kwargs = dict(retry=RetryPolicy(max_retries=5))
    if robust:
        kwargs.update(cancel_expired=True, max_queue=96,
                      brownout=BrownoutConfig(floor=0.9, window=32,
                                              min_samples=8))
    session = ServingSession(backend=backend, seed=seed, **kwargs)

    def lazyb(sla):
        return LazyBatching(SlackPredictor.build([wl], perf, sla),
                            max_batch=64)

    session.register("gold", wl, policy=lazyb(GOLD_SLA), shed_priority=1)
    session.register("bulk", wl, policy=lazyb(BULK_SLA), shed_priority=0)
    # same workload both tiers; only deadline + priority differ (the
    # arrivals heap orders submissions, so per-tier traces interleave)
    for tier, share, sla, off in (("gold", GOLD_SHARE, GOLD_SLA, 0),
                                  ("bulk", 1 - GOLD_SHARE, BULK_SLA, 1000)):
        trace = poisson_trace(wl, rate * share, duration, seed=seed + off)
        for r in trace.requests:
            r.sla = SLAClass(tier, sla)
            session.submit(r, model=tier)
    session.duration = duration
    stats = session.drain()
    pc = stats.per_class()
    return {
        "gold_attainment": pc["gold"]["sla_attainment"],
        "bulk_attainment": pc["bulk"]["sla_attainment"],
        "completed": len(stats.finished),
        "expired": len(stats.expired_requests),
        "shed": len(stats.shed_requests),
        "failed": len(stats.failed_requests),
        "retried": stats.retried,
        "faults": session.log.faults,
        "leaked_slots": backend.memory_stats().slots_live,
    }


def run(quick: bool = True) -> dict:
    rate = 8000.0                          # ~3x device capacity
    duration = 0.25 if quick else 1.0
    out, holds = {}, True
    for seed in (0, 1):
        base = _serve(seed, rate, duration, robust=False)
        rob = _serve(seed, rate, duration, robust=True)
        improves = rob["gold_attainment"] > base["gold_attainment"]
        no_leak = base["leaked_slots"] == 0 and rob["leaked_slots"] == 0
        holds = holds and improves and no_leak
        out[f"seed{seed}"] = {"baseline": base, "robust": rob,
                              "gold_improves": improves,
                              "no_leak": no_leak}
        print(f"  seed {seed}: gold attainment "
              f"{base['gold_attainment'] * 100:5.1f}% -> "
              f"{rob['gold_attainment'] * 100:5.1f}%  "
              f"(faults {rob['faults']}, retried {rob['retried']}, "
              f"expired {rob['expired']}, shed {rob['shed']}, "
              f"leaked {base['leaked_slots']}+{rob['leaked_slots']})")
    out["holds_on_both_seeds"] = holds
    verdict = "HOLDS" if holds else "VIOLATED"
    print(f"  robust gold-tier attainment strictly above baseline with "
          f"zero leaks on both seeds: {verdict}")
    return out


if __name__ == "__main__":
    run(quick=True)
